//! The event-driven core-pool simulator (rust/docs/DESIGN.md §9.2).
//!
//! A pool of `num_cores` identical cores serves a request trace: each
//! request occupies its model's allocated core count for the allocated
//! operating point's predicted service time (the `CostEngine`-tuned latency
//! — see [`super::allocator`]). Two event kinds drive the clock — arrivals
//! (from the seeded trace) and completions (a deterministic min-heap keyed
//! by `(finish time, start sequence)`). The whole simulation is a pure
//! function of its inputs: no wall clock, no global RNG, ties broken by
//! explicit sequence numbers.
//!
//! Entry point: the [`SimulationRun`] builder. The incremental engine
//! underneath ([`ChipSim`]) is also driven chip-by-chip by the fleet
//! simulator ([`super::fleet`]), which interleaves routing decisions with
//! per-chip event processing.

use std::collections::{BinaryHeap, VecDeque};

use super::queue::{DispatchPolicy, QueueSet, QueuedRequest};
use super::workload::Request;

/// The per-model operating point the cluster serves: a batch of `b`
/// requests for the model occupies `cores` cores for `service_at(b)`
/// milliseconds (`service_ms` is the single-request time, `b = 1`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelService {
    pub name: String,
    pub cores: usize,
    pub service_ms: f64,
    /// Predicted service time of one batched invocation at batch
    /// `index + 1`, ms — the allocator derives it from the tuned schedule
    /// through the cost engine (rust/docs/DESIGN.md §10). May be empty (or
    /// shorter than a requested batch): [`Self::service_at`] then
    /// extrapolates linearly from `service_ms`, i.e. assumes no
    /// amortization for unplanned batch sizes.
    pub batch_service_ms: Vec<f64>,
    /// Registry name of the hardware target the service times were planned
    /// for (rust/docs/DESIGN.md §11); empty when hand-built outside a plan.
    /// [`SimulationRun`] refuses to co-schedule services planned for
    /// different targets — a pool is one chip.
    pub target: String,
}

impl ModelService {
    /// An operating point with no batch table (single-request serving, or
    /// linear scaling under the `batch` policy) and no recorded target.
    pub fn new(name: impl Into<String>, cores: usize, service_ms: f64) -> ModelService {
        ModelService {
            name: name.into(),
            cores,
            service_ms,
            batch_service_ms: Vec::new(),
            target: String::new(),
        }
    }

    /// Attach the engine-predicted batched service times (entry `b - 1` is
    /// the invocation latency at batch `b`).
    pub fn with_batch_table(mut self, table: Vec<f64>) -> ModelService {
        self.batch_service_ms = table;
        self
    }

    /// Record the hardware target the service times were planned for.
    pub fn with_target(mut self, target: impl Into<String>) -> ModelService {
        self.target = target.into();
        self
    }

    /// Predicted service time of one invocation carrying `batch` requests.
    pub fn service_at(&self, batch: usize) -> f64 {
        batched_service_ms(&self.batch_service_ms, self.service_ms, batch)
    }
}

/// The one batched-invocation pricing rule, shared by [`ModelService`] and
/// the allocator's operating points: prefer the planned table (entry
/// `batch - 1`), extrapolate linearly from the single-request time past it
/// (no amortization assumed for unplanned batch sizes).
pub(crate) fn batched_service_ms(table: &[f64], single_ms: f64, batch: usize) -> f64 {
    assert!(batch >= 1, "batch must be at least 1");
    match table.get(batch - 1) {
        Some(&t) => t,
        None => batch as f64 * single_ms,
    }
}

/// Scenario configuration for one simulation run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterConfig {
    pub num_cores: usize,
    pub policy: DispatchPolicy,
}

/// What happened at one simulated instant (the pinned determinism surface:
/// two runs with the same inputs produce identical event vectors).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimEvent {
    pub time_ms: f64,
    pub kind: SimEventKind,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimEventKind {
    Arrive { id: u64, model: usize },
    Start { id: u64, cores: usize },
    Finish { id: u64, free_cores: usize },
}

/// Per-request completion record.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompletedRequest {
    pub id: u64,
    pub model: usize,
    pub arrival_ms: f64,
    pub start_ms: f64,
    pub finish_ms: f64,
    pub cores: usize,
    /// Size of the batched invocation this request rode in (1 under the
    /// single-request policies).
    pub batch: usize,
}

impl CompletedRequest {
    /// End-to-end latency: arrival to finish.
    pub fn e2e_ms(&self) -> f64 {
        self.finish_ms - self.arrival_ms
    }

    /// Time spent waiting for cores.
    pub fn queue_ms(&self) -> f64 {
        self.start_ms - self.arrival_ms
    }

    /// Time spent running.
    pub fn service_ms(&self) -> f64 {
        self.finish_ms - self.start_ms
    }
}

/// Outcome of one run: the event trace in simulated-time order plus the
/// completion records in finish order.
#[derive(Debug, Clone, PartialEq)]
pub struct SimResult {
    /// The full event trace — empty when the run disabled recording
    /// ([`SimulationRun::record_events`]); [`SimResult::events_processed`]
    /// still counts.
    pub events: Vec<SimEvent>,
    pub completed: Vec<CompletedRequest>,
    pub num_cores: usize,
    /// Events the simulation processed (arrivals, starts, finishes) —
    /// counted whether or not the trace was recorded, so `events/sec`
    /// throughput is measurable on trace-free hot-path runs. Equals
    /// `events.len()` when the trace is on.
    pub events_processed: u64,
}

impl SimResult {
    /// Simulated span from t=0 to the last completion.
    pub fn makespan_ms(&self) -> f64 {
        self.completed.iter().map(|c| c.finish_ms).fold(0.0, f64::max)
    }

    /// Core-milliseconds actually occupied by running invocations. A
    /// batched invocation occupies its cores once for the whole batch, so
    /// each rider request contributes its `1/batch` share (exact for
    /// batch 1, where every request is its own invocation).
    pub fn busy_core_ms(&self) -> f64 {
        self.completed
            .iter()
            .map(|c| c.service_ms() * c.cores as f64 / c.batch as f64)
            .sum()
    }

    /// Fraction of the pool's core-time spent serving (0 when nothing ran).
    pub fn utilization(&self) -> f64 {
        let span = self.makespan_ms();
        if span <= 0.0 || self.num_cores == 0 {
            return 0.0;
        }
        self.busy_core_ms() / (span * self.num_cores as f64)
    }

    /// Aggregate completions per second of simulated time (0 when nothing
    /// completed).
    pub fn throughput_rps(&self) -> f64 {
        let span = self.makespan_ms();
        if span <= 0.0 {
            return 0.0;
        }
        self.completed.len() as f64 / (span / 1000.0)
    }
}

/// A running invocation's key on the completion heap. The invocation body
/// (its riding requests) lives in a slab slot; the heap holds only this
/// `Copy` triple, so every sift moves a few words instead of a whole
/// request batch. `BinaryHeap` is a max-heap, so `Ord` is reversed to pop
/// the *earliest* `(finish_ms, seq)` first; `seq` is the start order,
/// making equal-time pops deterministic (`slot` never orders).
#[derive(Debug, Clone, Copy)]
struct HeapEntry {
    finish_ms: f64,
    seq: u64,
    slot: usize,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for HeapEntry {}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .finish_ms
            .total_cmp(&self.finish_ms)
            .then(other.seq.cmp(&self.seq))
    }
}

/// The slab-resident body of a running invocation — one request under the
/// single-request policies, up to `max_batch` same-model requests under
/// the `batch` policy. Slots are recycled LIFO, so a long run reuses a
/// bounded working set instead of reallocating per dispatch.
#[derive(Debug)]
struct RunningBatch {
    start_ms: f64,
    /// When the invocation completes — mirrors its heap entry, so the
    /// router's backlog estimate reads the slab instead of walking the heap.
    finish_ms: f64,
    /// Cores the invocation occupies (the model's allocation, once for the
    /// whole batch).
    cores: usize,
    /// The requests riding the invocation, in arrival order.
    reqs: Vec<QueuedRequest>,
}

/// Builder for one deterministic simulation of the core pool — the single
/// entry point behind `serve-sim`, the fleet per-chip event loops
/// ([`super::fleet`]), and the deprecated [`simulate`] / [`simulate_with`]
/// shims.
///
/// Defaults: empty trace, open loop, event recording on.
///
/// ```
/// use dlfusion::serving::{ClusterConfig, DispatchPolicy, ModelService,
///                         Request, SimulationRun};
///
/// let cfg = ClusterConfig { num_cores: 4, policy: DispatchPolicy::Fifo };
/// let services = [ModelService::new("m", 2, 10.0)];
/// let trace = [Request { id: 0, model: 0, arrival_ms: 0.0 }];
/// let result = SimulationRun::new(&cfg, &services)
///     .trace(&trace)
///     .record_events(false)
///     .run()
///     .expect("valid run");
/// assert_eq!(result.completed.len(), 1);
/// ```
#[derive(Debug, Clone)]
pub struct SimulationRun<'a> {
    cfg: ClusterConfig,
    services: &'a [ModelService],
    trace: &'a [Request],
    closed_loop: Option<usize>,
    record_events: bool,
}

impl<'a> SimulationRun<'a> {
    /// A run of `services` over the `cfg` pool.
    pub fn new(cfg: &ClusterConfig, services: &'a [ModelService]) -> SimulationRun<'a> {
        SimulationRun {
            cfg: *cfg,
            services,
            trace: &[],
            closed_loop: None,
            record_events: true,
        }
    }

    /// The arrival trace to replay (sorted by arrival time).
    pub fn trace(mut self, trace: &'a [Request]) -> SimulationRun<'a> {
        self.trace = trace;
        self
    }

    /// `Some(k)`: fixed-population closed loop — only the first `k` trace
    /// entries arrive up front; each completion injects the next backlogged
    /// entry at the completion instant. `None` (the default): open loop,
    /// the trace arrives as timestamped.
    pub fn closed_loop(mut self, population: Option<usize>) -> SimulationRun<'a> {
        self.closed_loop = population;
        self
    }

    /// Whether to record the [`SimEvent`] trace (default on). The trace
    /// exists for inspection and determinism pinning; on throughput runs it
    /// is pure overhead (three records per request). Disabling it changes
    /// nothing else — completions, makespan, and
    /// [`SimResult::events_processed`] are bit-identical —
    /// and `SimResult::events` comes back empty.
    pub fn record_events(mut self, record: bool) -> SimulationRun<'a> {
        self.record_events = record;
        self
    }

    /// Validate the inputs and run the simulation to completion.
    ///
    /// Completions at the same instant as an arrival are processed first,
    /// so freed cores are visible to the arrival's dispatch. Under
    /// [`DispatchPolicy::Batch`] a third event kind joins arrivals and
    /// completions: the *flush deadline* of a held partial batch
    /// (`oldest arrival + max_wait_ms`), processed after any completion or
    /// arrival at the same instant so a just-freed core or a just-arrived
    /// request is visible to the flush. The simulation stays a pure
    /// function of its inputs.
    pub fn run(self) -> Result<SimResult, String> {
        let mut chip = ChipSim::new(&self.cfg, self.services, self.record_events)?;
        chip.load_trace(self.trace, self.closed_loop)?;
        chip.advance(None);
        Ok(chip.into_result())
    }
}

/// Run the discrete-event simulation of `trace` over the core pool.
///
/// `closed_loop`: when `Some(k)`, only the first `k` trace entries arrive up
/// front; each completion injects the next backlogged entry at the
/// completion instant (a fixed-population closed loop).
#[deprecated(note = "build a `SimulationRun`: \
                     SimulationRun::new(cfg, services).trace(trace).run()")]
pub fn simulate(cfg: &ClusterConfig, services: &[ModelService],
                trace: &[Request], closed_loop: Option<usize>)
                -> Result<SimResult, String> {
    SimulationRun::new(cfg, services).trace(trace).closed_loop(closed_loop).run()
}

/// [`simulate`], with the [`SimEvent`] trace recording made optional —
/// [`SimulationRun::record_events`] as a free function.
#[deprecated(note = "build a `SimulationRun` with .record_events(...)")]
pub fn simulate_with(cfg: &ClusterConfig, services: &[ModelService],
                     trace: &[Request], closed_loop: Option<usize>,
                     record_events: bool)
                     -> Result<SimResult, String> {
    SimulationRun::new(cfg, services)
        .trace(trace)
        .closed_loop(closed_loop)
        .record_events(record_events)
        .run()
}

/// The incremental single-chip engine behind [`SimulationRun`]: validated
/// pool state plus the three event sources (completions, queued arrivals,
/// flush deadlines). [`SimulationRun::run`] loads a whole trace and drains
/// it in one [`ChipSim::advance`]; the fleet loop ([`super::fleet`])
/// instead advances every chip to each arrival instant, consults the
/// router against the chips' exact queue/backlog state, and injects the
/// routed request via [`ChipSim::arrive`]. Either way each chip processes
/// the same `(time, rank)` event sequence — which is why a one-chip fleet
/// is bit-identical to a single-pool run.
#[derive(Debug)]
pub(crate) struct ChipSim<'a> {
    num_cores: usize,
    policy: DispatchPolicy,
    batch_knobs: Option<(usize, f64)>,
    services: &'a [ModelService],
    record_events: bool,
    closed_loop: bool,
    arrivals: VecDeque<Request>,
    backlog: VecDeque<Request>,
    events: Vec<SimEvent>,
    events_processed: u64,
    completed: Vec<CompletedRequest>,
    queues: QueueSet,
    heap: BinaryHeap<HeapEntry>,
    slab: Vec<Option<RunningBatch>>,
    free_slots: Vec<usize>,
    free: usize,
    seq: u64,
}

impl<'a> ChipSim<'a> {
    /// Validate the pool configuration and services; build an idle chip.
    pub(crate) fn new(cfg: &ClusterConfig, services: &'a [ModelService],
                      record_events: bool) -> Result<ChipSim<'a>, String> {
        if cfg.num_cores == 0 {
            return Err("cluster has no cores".into());
        }
        let batch_knobs = match cfg.policy {
            DispatchPolicy::Batch { max_batch, max_wait_ms } => {
                if max_batch == 0 {
                    return Err("batch policy needs max_batch >= 1".into());
                }
                if !(max_wait_ms >= 0.0) {
                    return Err(format!(
                        "batch policy needs a non-negative max_wait_ms, got {max_wait_ms}"));
                }
                Some((max_batch, max_wait_ms))
            }
            _ => None,
        };
        // One pool is one chip: services planned for different hardware
        // targets cannot share it (their service times are in different
        // "units"). Heterogeneity lives across fleet chips, never within one.
        let mut planned_target: Option<&str> = None;
        for s in services {
            if s.target.is_empty() {
                continue;
            }
            match planned_target {
                None => planned_target = Some(s.target.as_str()),
                Some(first) if first != s.target => {
                    return Err(crate::accel::TargetError::MixedTargets {
                        first: first.to_string(),
                        second: s.target.clone(),
                    }
                    .to_string());
                }
                Some(_) => {}
            }
        }
        for s in services {
            if s.cores == 0 || s.cores > cfg.num_cores {
                return Err(format!(
                    "model '{}' allocated {} cores outside 1..={}",
                    s.name, s.cores, cfg.num_cores));
            }
            if !(s.service_ms > 0.0) {
                return Err(format!(
                    "model '{}' has non-positive service time {} ms",
                    s.name, s.service_ms));
            }
            if let Some(&bad) = s.batch_service_ms.iter().find(|&&t| !(t > 0.0)) {
                return Err(format!(
                    "model '{}' has a non-positive batched service time {bad} ms",
                    s.name));
            }
        }
        Ok(ChipSim {
            num_cores: cfg.num_cores,
            policy: cfg.policy,
            batch_knobs,
            services,
            record_events,
            closed_loop: false,
            arrivals: VecDeque::new(),
            backlog: VecDeque::new(),
            events: Vec::new(),
            events_processed: 0,
            completed: Vec::new(),
            queues: QueueSet::new(services.len()),
            heap: BinaryHeap::new(),
            slab: Vec::new(),
            free_slots: Vec::new(),
            free: cfg.num_cores,
            seq: 0,
        })
    }

    /// Load a whole arrival trace (the single-pool path): validate it and
    /// queue every entry as an internal arrival event.
    fn load_trace(&mut self, trace: &[Request],
                  closed_loop: Option<usize>) -> Result<(), String> {
        for w in trace.windows(2) {
            if w[1].arrival_ms < w[0].arrival_ms {
                return Err("trace is not sorted by arrival time".into());
            }
        }
        if let Some(r) = trace.iter().find(|r| r.model >= self.services.len()) {
            return Err(format!(
                "request {} references model {} but only {} are allocated",
                r.id, r.model, self.services.len()));
        }
        // Closed-loop injections append at completion instants, which stay
        // ordered only because every closed-loop trace arrives at one
        // instant (what `generate_trace` emits for
        // `ArrivalProcess::ClosedLoop`).
        if closed_loop.is_some()
            && trace.windows(2).any(|w| w[1].arrival_ms != w[0].arrival_ms)
        {
            return Err("closed-loop simulation expects a simultaneous-arrival \
                        trace (generate with ArrivalProcess::ClosedLoop)"
                .into());
        }
        self.arrivals = trace.iter().copied().collect();
        if let Some(k) = closed_loop {
            self.closed_loop = true;
            let k = k.max(1);
            if self.arrivals.len() > k {
                self.backlog = self.arrivals.split_off(k);
            }
        }
        // Every request arrives, starts, and finishes exactly once
        // (closed-loop runs replay the same trace entries), so the recorded
        // trace is exactly three events per request: pre-size it once.
        if self.record_events {
            self.events.reserve(trace.len() * 3);
        }
        self.completed.reserve(trace.len());
        Ok(())
    }

    /// Process events in `(time, rank)` order — completions rank 0,
    /// arrivals rank 1, flush deadlines rank 2 — until every source is dry
    /// or, with `limit = Some(t)`, until the next event would sort at or
    /// after an external arrival at `t` (a rank-1 slot): completions at `t`
    /// still run first, same-instant flush deadlines wait until after the
    /// arrival is injected. The fleet loop alternates `advance(Some(t))` /
    /// [`Self::arrive`] per routed request and finishes with
    /// `advance(None)`.
    pub(crate) fn advance(&mut self, limit: Option<f64>) {
        loop {
            let next_arrival = self.arrivals.front().map(|r| r.arrival_ms);
            let next_finish = self.heap.peek().map(|c| c.finish_ms);
            let next_deadline = self.next_deadline();
            // Tie order at one instant: completions first (free cores before
            // dispatching), then arrivals (a request arriving exactly at a
            // flush deadline joins the batch), then deadlines.
            let mut choice: Option<(f64, u8)> = None;
            for (t, rank) in
                [(next_finish, 0u8), (next_arrival, 1), (next_deadline, 2)]
            {
                if let Some(t) = t {
                    let better = match choice {
                        None => true,
                        Some(best) => (t, rank) < best,
                    };
                    if better {
                        choice = Some((t, rank));
                    }
                }
            }
            let Some((event_ms, rank)) = choice else { break };
            if let Some(lim) = limit {
                if event_ms > lim || (event_ms == lim && rank >= 1) {
                    break;
                }
            }
            let now = match rank {
                0 => self.complete_one(),
                1 => {
                    let r = self.arrivals.pop_front().unwrap();
                    self.admit(r);
                    r.arrival_ms
                }
                // Flush deadline: only the clock advances; the dispatch pass
                // below releases every matured batch.
                _ => event_ms,
            };
            self.dispatch_at(now);
        }
    }

    /// Inject an external (router-chosen) arrival at its own instant. The
    /// caller must have advanced the chip to the arrival time first
    /// (`advance(Some(arrival_ms))`), so this lands in the exact `(time,
    /// rank)` slot an internally queued arrival would occupy.
    pub(crate) fn arrive(&mut self, r: Request) {
        debug_assert!(r.model < self.services.len());
        self.admit(r);
        self.dispatch_at(r.arrival_ms);
    }

    /// Requests queued (arrived, not yet dispatched) — the admission
    /// controller's shed signal.
    pub(crate) fn waiting(&self) -> usize {
        self.queues.len()
    }

    /// Estimated time to drain everything on the chip at `now`, in ms: the
    /// remaining core-ms of running invocations plus the single-request
    /// core-ms of every queued request, normalized by the pool width. The
    /// least-loaded router's join-shortest-expected-delay signal — an
    /// estimate (queued work is priced at batch 1), but a deterministic
    /// one.
    pub(crate) fn backlog_ms(&self, now: f64) -> f64 {
        let mut core_ms = 0.0;
        for b in self.slab.iter().flatten() {
            core_ms += (b.finish_ms - now).max(0.0) * b.cores as f64;
        }
        for q in self.queues.iter() {
            core_ms += q.service_ms * q.cores as f64;
        }
        core_ms / self.num_cores as f64
    }

    /// Tear down into the run's result. Debug builds assert the pool
    /// drained (every admitted request completed, all cores free).
    pub(crate) fn into_result(self) -> SimResult {
        debug_assert!(self.queues.is_empty(), "validated requests cannot strand");
        debug_assert_eq!(self.free, self.num_cores);
        debug_assert!(self.slab.iter().all(Option::is_none),
                      "no invocation left running");
        SimResult {
            events: self.events,
            completed: self.completed,
            num_cores: self.num_cores,
            events_processed: self.events_processed,
        }
    }

    /// The earliest flush deadline among held partial batches that could
    /// dispatch right now (batch policy only). Anything not dispatchable
    /// now — cores busy, or already a full batch — needs no timer: the
    /// completion or arrival that changes that re-runs the dispatch pass.
    fn next_deadline(&self) -> Option<f64> {
        let (max_batch, max_wait_ms) = self.batch_knobs?;
        let mut deadline: Option<f64> = None;
        for (m, svc) in self.services.iter().enumerate() {
            let Some(head) = self.queues.head(m) else { continue };
            if svc.cores > self.free || self.queues.len_for(m) >= max_batch {
                continue;
            }
            let d = head.arrival_ms + max_wait_ms;
            let sooner = match deadline {
                None => true,
                Some(cur) => d < cur,
            };
            if sooner {
                deadline = Some(d);
            }
        }
        deadline
    }

    /// Pop the earliest completion: free its cores, record every rider,
    /// and (closed loop) inject one backlogged arrival per rider at the
    /// completion instant. Returns the completion time.
    fn complete_one(&mut self) -> f64 {
        let entry = self.heap.pop().unwrap();
        let c = self.slab[entry.slot].take().expect("heap entry has a live slot");
        self.free_slots.push(entry.slot);
        self.free += c.cores;
        let batch = c.reqs.len();
        for r in &c.reqs {
            self.events_processed += 1;
            if self.record_events {
                self.events.push(SimEvent {
                    time_ms: entry.finish_ms,
                    kind: SimEventKind::Finish { id: r.id, free_cores: self.free },
                });
            }
            self.completed.push(CompletedRequest {
                id: r.id,
                model: r.model,
                arrival_ms: r.arrival_ms,
                start_ms: c.start_ms,
                finish_ms: entry.finish_ms,
                cores: c.cores,
                batch,
            });
        }
        if self.closed_loop {
            for _ in 0..batch {
                if let Some(mut nxt) = self.backlog.pop_front() {
                    nxt.arrival_ms = entry.finish_ms;
                    self.arrivals.push_back(nxt);
                }
            }
        }
        entry.finish_ms
    }

    /// Record an arrival and queue it at its model's operating point.
    fn admit(&mut self, r: Request) {
        self.events_processed += 1;
        if self.record_events {
            self.events.push(SimEvent {
                time_ms: r.arrival_ms,
                kind: SimEventKind::Arrive { id: r.id, model: r.model },
            });
        }
        let svc = &self.services[r.model];
        self.queues.push(QueuedRequest {
            id: r.id,
            model: r.model,
            arrival_ms: r.arrival_ms,
            cores: svc.cores,
            service_ms: svc.service_ms,
        });
    }

    /// Seat a running invocation in the slab and key it on the heap.
    fn launch(&mut self, body: RunningBatch) {
        self.seq += 1;
        let finish_ms = body.finish_ms;
        let seq = self.seq;
        let slot = match self.free_slots.pop() {
            Some(s) => {
                self.slab[s] = Some(body);
                s
            }
            None => {
                self.slab.push(Some(body));
                self.slab.len() - 1
            }
        };
        self.heap.push(HeapEntry { finish_ms, seq, slot });
    }

    /// Dispatch at the current instant (runs after every event).
    fn dispatch_at(&mut self, now: f64) {
        match self.batch_knobs {
            None => {
                // Single-request policies: work-conserving fit-filtered pops.
                while let Some(q) = self.queues.pop_fitting(self.policy, self.free) {
                    self.free -= q.cores;
                    self.events_processed += 1;
                    if self.record_events {
                        self.events.push(SimEvent {
                            time_ms: now,
                            kind: SimEventKind::Start { id: q.id, cores: q.cores },
                        });
                    }
                    let finish_ms = now + q.service_ms;
                    let cores = q.cores;
                    self.launch(RunningBatch {
                        start_ms: now,
                        finish_ms,
                        cores,
                        reqs: vec![q],
                    });
                }
            }
            Some((max_batch, max_wait_ms)) => {
                // Batch former: release every model whose queue holds a full
                // batch or whose oldest request has hit the wait deadline,
                // longest-waiting model first (ties by request id).
                loop {
                    let mut pick: Option<(usize, (f64, u64))> = None;
                    for (m, svc) in self.services.iter().enumerate() {
                        let Some(head) = self.queues.head(m) else { continue };
                        if svc.cores > self.free {
                            continue;
                        }
                        let mature = self.queues.len_for(m) >= max_batch
                            || now >= head.arrival_ms + max_wait_ms;
                        if !mature {
                            continue;
                        }
                        let key = (head.arrival_ms, head.id);
                        let better = match pick {
                            None => true,
                            Some((_, best)) => key < best,
                        };
                        if better {
                            pick = Some((m, key));
                        }
                    }
                    let Some((m, _)) = pick else { break };
                    let reqs = self.queues.pop_front_n(m, max_batch);
                    let cores = self.services[m].cores;
                    let service = self.services[m].service_at(reqs.len());
                    self.free -= cores;
                    for r in &reqs {
                        self.events_processed += 1;
                        if self.record_events {
                            self.events.push(SimEvent {
                                time_ms: now,
                                kind: SimEventKind::Start { id: r.id, cores },
                            });
                        }
                    }
                    self.launch(RunningBatch {
                        start_ms: now,
                        finish_ms: now + service,
                        cores,
                        reqs,
                    });
                }
            }
        }
    }
}

#[cfg(test)]
// The legacy shims stay covered until they are removed.
#[allow(deprecated)]
mod tests {
    use super::*;

    fn svc(name: &str, cores: usize, ms: f64) -> ModelService {
        ModelService::new(name, cores, ms)
    }

    fn req(id: u64, model: usize, arrival: f64) -> Request {
        Request { id, model, arrival_ms: arrival }
    }

    #[test]
    fn two_core_pool_runs_pair_then_queues_third() {
        let cfg = ClusterConfig { num_cores: 2, policy: DispatchPolicy::Fifo };
        let services = [svc("m", 1, 10.0)];
        let trace = [req(0, 0, 0.0), req(1, 0, 0.0), req(2, 0, 0.0)];
        let r = simulate(&cfg, &services, &trace, None).unwrap();
        assert_eq!(r.completed.len(), 3);
        // 0 and 1 run immediately; 2 waits for the first finish at 10 ms.
        assert_eq!(r.completed[2].id, 2);
        assert_eq!(r.completed[2].start_ms, 10.0);
        assert_eq!(r.completed[2].finish_ms, 20.0);
        assert_eq!(r.completed[2].queue_ms(), 10.0);
        assert_eq!(r.makespan_ms(), 20.0);
        // 30 core-ms busy over 2 cores * 20 ms.
        assert!((r.utilization() - 0.75).abs() < 1e-12);
        assert!((r.throughput_rps() - 150.0).abs() < 1e-9);
    }

    #[test]
    fn event_trace_is_ordered_and_deterministic() {
        let cfg = ClusterConfig { num_cores: 4, policy: DispatchPolicy::Fifo };
        let services = [svc("a", 2, 7.0), svc("b", 1, 3.0)];
        let trace = [req(0, 0, 0.0), req(1, 1, 1.0), req(2, 0, 1.0),
                     req(3, 1, 2.0)];
        let r1 = simulate(&cfg, &services, &trace, None).unwrap();
        let r2 = simulate(&cfg, &services, &trace, None).unwrap();
        assert_eq!(r1, r2);
        for w in r1.events.windows(2) {
            assert!(w[1].time_ms >= w[0].time_ms, "{:?}", r1.events);
        }
        // Every request arrives, starts, and finishes exactly once.
        let count = |f: &dyn Fn(&SimEventKind) -> bool| {
            r1.events.iter().filter(|e| f(&e.kind)).count()
        };
        assert_eq!(count(&|k| matches!(k, SimEventKind::Arrive { .. })), 4);
        assert_eq!(count(&|k| matches!(k, SimEventKind::Start { .. })), 4);
        assert_eq!(count(&|k| matches!(k, SimEventKind::Finish { .. })), 4);
    }

    #[test]
    fn completion_frees_cores_before_simultaneous_arrival() {
        let cfg = ClusterConfig { num_cores: 2, policy: DispatchPolicy::Fifo };
        let services = [svc("m", 2, 10.0)];
        // Second request arrives exactly when the first finishes: it must
        // start immediately (cores freed first), not queue.
        let trace = [req(0, 0, 0.0), req(1, 0, 10.0)];
        let r = simulate(&cfg, &services, &trace, None).unwrap();
        assert_eq!(r.completed[1].queue_ms(), 0.0);
        assert_eq!(r.completed[1].finish_ms, 20.0);
    }

    #[test]
    fn narrow_requests_overtake_a_blocked_wide_head() {
        let cfg = ClusterConfig { num_cores: 4, policy: DispatchPolicy::Fifo };
        let services = [svc("wide", 3, 10.0), svc("narrow", 1, 10.0)];
        // While request 0 runs (3 cores), wide request 1 can't fit in the
        // one free core but narrow request 2 can.
        let trace = [req(0, 0, 0.0), req(1, 0, 1.0), req(2, 1, 2.0)];
        let r = simulate(&cfg, &services, &trace, None).unwrap();
        let by_id = |id: u64| *r.completed.iter().find(|c| c.id == id).unwrap();
        assert_eq!(by_id(2).start_ms, 2.0, "narrow dispatches on arrival");
        assert_eq!(by_id(1).start_ms, 10.0, "wide waits for request 0");
    }

    #[test]
    fn closed_loop_keeps_population_and_injects_on_completion() {
        let cfg = ClusterConfig { num_cores: 2, policy: DispatchPolicy::Fifo };
        let services = [svc("m", 1, 5.0)];
        let trace: Vec<Request> = (0..6).map(|i| req(i, 0, 0.0)).collect();
        let r = simulate(&cfg, &services, &trace, Some(2)).unwrap();
        assert_eq!(r.completed.len(), 6);
        // Population 2 on 2 cores: perfectly pipelined, zero queueing.
        assert!(r.completed.iter().all(|c| c.queue_ms() == 0.0), "{r:?}");
        assert_eq!(r.makespan_ms(), 15.0);
        assert!((r.utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn service_at_prefers_the_table_and_extrapolates_linearly() {
        let s = svc("m", 2, 10.0).with_batch_table(vec![10.0, 14.0, 17.0]);
        assert_eq!(s.service_at(1), 10.0);
        assert_eq!(s.service_at(3), 17.0);
        // Past the table: linear in the single-request time.
        assert_eq!(s.service_at(5), 50.0);
        // No table at all: pure linear scaling.
        assert_eq!(svc("m", 2, 10.0).service_at(4), 40.0);
    }

    #[test]
    fn full_batch_dispatches_immediately_and_remainder_flushes_on_wait() {
        let cfg = ClusterConfig {
            num_cores: 2,
            policy: DispatchPolicy::Batch { max_batch: 2, max_wait_ms: 5.0 },
        };
        let services = [svc("m", 2, 10.0).with_batch_table(vec![10.0, 12.0])];
        let trace = [req(0, 0, 0.0), req(1, 0, 0.0), req(2, 0, 0.0)];
        let r = simulate(&cfg, &services, &trace, None).unwrap();
        assert_eq!(r.completed.len(), 3);
        let by_id = |id: u64| *r.completed.iter().find(|c| c.id == id).unwrap();
        // Requests 0 and 1 ride one batch-2 invocation: 12 ms, not 20.
        assert_eq!(by_id(0).batch, 2);
        assert_eq!(by_id(1).finish_ms, 12.0);
        assert_eq!(by_id(0).finish_ms, by_id(1).finish_ms);
        // Request 2 is a held partial batch; when the cores free at 12 ms
        // its 5 ms wait has long matured, so it flushes alone.
        assert_eq!(by_id(2).batch, 1);
        assert_eq!(by_id(2).start_ms, 12.0);
        assert_eq!(by_id(2).finish_ms, 22.0);
        assert_eq!(r.makespan_ms(), 22.0);
        // Core-time accounting charges each invocation once, not once per
        // rider: the pool was busy the whole 22 ms (24 + 20 core-ms on 2
        // cores), never 200% busy.
        assert!((r.busy_core_ms() - 44.0).abs() < 1e-12, "{}", r.busy_core_ms());
        assert!((r.utilization() - 1.0).abs() < 1e-12, "{}", r.utilization());
    }

    #[test]
    fn partial_batch_flushes_at_the_wait_deadline() {
        let cfg = ClusterConfig {
            num_cores: 4,
            policy: DispatchPolicy::Batch { max_batch: 4, max_wait_ms: 3.0 },
        };
        let services = [svc("m", 2, 10.0)];
        // A lone request on an idle pool: batching holds it exactly
        // max_wait_ms, then gives up on a fuller batch.
        let trace = [req(0, 0, 1.0)];
        let r = simulate(&cfg, &services, &trace, None).unwrap();
        assert_eq!(r.completed[0].start_ms, 4.0);
        assert_eq!(r.completed[0].queue_ms(), 3.0);
        assert_eq!(r.completed[0].batch, 1);
    }

    #[test]
    fn arrival_completes_a_held_batch_before_its_deadline() {
        let cfg = ClusterConfig {
            num_cores: 4,
            policy: DispatchPolicy::Batch { max_batch: 2, max_wait_ms: 5.0 },
        };
        let services = [svc("m", 2, 10.0).with_batch_table(vec![10.0, 13.0])];
        let trace = [req(0, 0, 0.0), req(1, 0, 1.0)];
        let r = simulate(&cfg, &services, &trace, None).unwrap();
        // The second arrival fills the batch at t=1; nobody waits to t=5.
        let by_id = |id: u64| *r.completed.iter().find(|c| c.id == id).unwrap();
        assert_eq!(by_id(0).start_ms, 1.0);
        assert_eq!(by_id(0).batch, 2);
        assert_eq!(by_id(0).finish_ms, 14.0);
        assert_eq!(by_id(1).finish_ms, 14.0);
    }

    #[test]
    fn max_batch_one_reproduces_fifo_exactly() {
        let services = [svc("a", 2, 7.0), svc("b", 1, 3.0)];
        let trace = [req(0, 0, 0.0), req(1, 1, 1.0), req(2, 0, 1.0),
                     req(3, 1, 2.0)];
        let fifo = simulate(
            &ClusterConfig { num_cores: 4, policy: DispatchPolicy::Fifo },
            &services, &trace, None).unwrap();
        let batch1 = simulate(
            &ClusterConfig {
                num_cores: 4,
                policy: DispatchPolicy::Batch { max_batch: 1, max_wait_ms: 9.0 },
            },
            &services, &trace, None).unwrap();
        assert_eq!(fifo.events, batch1.events);
        // Completion records differ only in the (all-ones) batch field.
        for (f, b) in fifo.completed.iter().zip(&batch1.completed) {
            assert_eq!((f.id, f.start_ms, f.finish_ms), (b.id, b.start_ms, b.finish_ms));
            assert_eq!(b.batch, 1);
        }
    }

    #[test]
    fn batch_policy_is_deterministic() {
        let cfg = ClusterConfig {
            num_cores: 4,
            policy: DispatchPolicy::Batch { max_batch: 3, max_wait_ms: 2.0 },
        };
        let services = [svc("a", 2, 7.0).with_batch_table(vec![7.0, 9.0, 10.0]),
                        svc("b", 1, 3.0)];
        let trace = [req(0, 0, 0.0), req(1, 1, 0.5), req(2, 0, 1.0),
                     req(3, 0, 1.5), req(4, 1, 6.0)];
        let r1 = simulate(&cfg, &services, &trace, None).unwrap();
        let r2 = simulate(&cfg, &services, &trace, None).unwrap();
        assert_eq!(r1, r2);
        assert_eq!(r1.completed.len(), 5);
        for w in r1.events.windows(2) {
            assert!(w[1].time_ms >= w[0].time_ms, "{:?}", r1.events);
        }
    }

    #[test]
    fn rejects_bad_batch_knobs_and_tables() {
        let services = [svc("m", 1, 1.0)];
        let trace = [req(0, 0, 0.0)];
        let err = simulate(
            &ClusterConfig {
                num_cores: 2,
                policy: DispatchPolicy::Batch { max_batch: 0, max_wait_ms: 1.0 },
            },
            &services, &trace, None).unwrap_err();
        assert!(err.contains("max_batch"), "{err}");
        let err = simulate(
            &ClusterConfig {
                num_cores: 2,
                policy: DispatchPolicy::Batch { max_batch: 2, max_wait_ms: -1.0 },
            },
            &services, &trace, None).unwrap_err();
        assert!(err.contains("max_wait_ms"), "{err}");
        let bad = [svc("m", 1, 1.0).with_batch_table(vec![1.0, 0.0])];
        let err = simulate(
            &ClusterConfig { num_cores: 2, policy: DispatchPolicy::Fifo },
            &bad, &trace, None).unwrap_err();
        assert!(err.contains("batched service time"), "{err}");
    }

    #[test]
    fn rejects_invalid_inputs() {
        let cfg = ClusterConfig { num_cores: 4, policy: DispatchPolicy::Fifo };
        let err = simulate(&cfg, &[svc("m", 8, 1.0)], &[req(0, 0, 0.0)], None)
            .unwrap_err();
        assert!(err.contains("outside"), "{err}");
        let err = simulate(&cfg, &[svc("m", 1, 0.0)], &[req(0, 0, 0.0)], None)
            .unwrap_err();
        assert!(err.contains("non-positive"), "{err}");
        let err = simulate(&cfg, &[svc("m", 1, 1.0)], &[req(0, 3, 0.0)], None)
            .unwrap_err();
        assert!(err.contains("references model"), "{err}");
        let err = simulate(&cfg, &[svc("m", 1, 1.0)],
                           &[req(0, 0, 5.0), req(1, 0, 1.0)], None)
            .unwrap_err();
        assert!(err.contains("sorted"), "{err}");
        // A closed loop over a spread-out trace is rejected (injection
        // order would not be time-ordered).
        let err = simulate(&cfg, &[svc("m", 1, 1.0)],
                           &[req(0, 0, 0.0), req(1, 0, 5.0)], Some(1))
            .unwrap_err();
        assert!(err.contains("simultaneous"), "{err}");
    }

    #[test]
    fn empty_trace_is_an_empty_result() {
        let cfg = ClusterConfig { num_cores: 2, policy: DispatchPolicy::Fifo };
        let r = simulate(&cfg, &[svc("m", 1, 1.0)], &[], None).unwrap();
        assert!(r.events.is_empty());
        assert_eq!(r.events_processed, 0);
        assert_eq!(r.throughput_rps(), 0.0);
        assert_eq!(r.utilization(), 0.0);
    }

    #[test]
    fn trace_counts_three_events_per_request() {
        let cfg = ClusterConfig {
            num_cores: 4,
            policy: DispatchPolicy::Batch { max_batch: 3, max_wait_ms: 2.0 },
        };
        let services = [svc("a", 2, 7.0).with_batch_table(vec![7.0, 9.0, 10.0]),
                        svc("b", 1, 3.0)];
        let trace = [req(0, 0, 0.0), req(1, 1, 0.5), req(2, 0, 1.0),
                     req(3, 0, 1.5), req(4, 1, 6.0)];
        let r = simulate(&cfg, &services, &trace, None).unwrap();
        assert_eq!(r.events_processed, 3 * trace.len() as u64);
        assert_eq!(r.events.len() as u64, r.events_processed);
    }

    #[test]
    fn disabling_the_trace_changes_nothing_else() {
        let cfg = ClusterConfig {
            num_cores: 4,
            policy: DispatchPolicy::Batch { max_batch: 3, max_wait_ms: 2.0 },
        };
        let services = [svc("a", 2, 7.0).with_batch_table(vec![7.0, 9.0, 10.0]),
                        svc("b", 1, 3.0)];
        let trace = [req(0, 0, 0.0), req(1, 1, 0.5), req(2, 0, 1.0),
                     req(3, 0, 1.5), req(4, 1, 6.0)];
        let on = simulate(&cfg, &services, &trace, None).unwrap();
        let off = simulate_with(&cfg, &services, &trace, None, false).unwrap();
        assert!(off.events.is_empty());
        assert_eq!(off.completed, on.completed);
        assert_eq!(off.events_processed, on.events_processed);
        assert_eq!(off.makespan_ms(), on.makespan_ms());
    }

    #[test]
    fn builder_and_deprecated_shims_are_bit_identical() {
        let cfg = ClusterConfig {
            num_cores: 4,
            policy: DispatchPolicy::Batch { max_batch: 3, max_wait_ms: 2.0 },
        };
        let services = [svc("a", 2, 7.0).with_batch_table(vec![7.0, 9.0, 10.0]),
                        svc("b", 1, 3.0)];
        let trace = [req(0, 0, 0.0), req(1, 1, 0.5), req(2, 0, 1.0),
                     req(3, 0, 1.5), req(4, 1, 6.0)];
        let built =
            SimulationRun::new(&cfg, &services).trace(&trace).run().unwrap();
        assert_eq!(built, simulate(&cfg, &services, &trace, None).unwrap());
        let quiet = SimulationRun::new(&cfg, &services)
            .trace(&trace)
            .record_events(false)
            .run()
            .unwrap();
        assert_eq!(quiet,
                   simulate_with(&cfg, &services, &trace, None, false).unwrap());
        // Closed loop too (simultaneous-arrival trace).
        let fifo = ClusterConfig { num_cores: 2, policy: DispatchPolicy::Fifo };
        let pool = [svc("m", 1, 5.0)];
        let closed: Vec<Request> = (0..6).map(|i| req(i, 0, 0.0)).collect();
        let built = SimulationRun::new(&fifo, &pool)
            .trace(&closed)
            .closed_loop(Some(2))
            .run()
            .unwrap();
        assert_eq!(built, simulate(&fifo, &pool, &closed, Some(2)).unwrap());
    }

    #[test]
    fn incremental_arrival_injection_matches_whole_trace_run() {
        // The fleet drive: advance to each arrival instant, then inject. The
        // batch policy exercises the flush-deadline rank alongside the
        // external arrivals.
        let cfg = ClusterConfig {
            num_cores: 4,
            policy: DispatchPolicy::Batch { max_batch: 3, max_wait_ms: 2.0 },
        };
        let services = [svc("a", 2, 7.0).with_batch_table(vec![7.0, 9.0, 10.0]),
                        svc("b", 1, 3.0)];
        let trace = [req(0, 0, 0.0), req(1, 1, 0.5), req(2, 0, 1.0),
                     req(3, 0, 1.5), req(4, 1, 6.0), req(5, 0, 6.0)];
        let whole =
            SimulationRun::new(&cfg, &services).trace(&trace).run().unwrap();
        let mut chip = ChipSim::new(&cfg, &services, true).unwrap();
        for r in &trace {
            chip.advance(Some(r.arrival_ms));
            chip.arrive(*r);
        }
        chip.advance(None);
        assert_eq!(chip.into_result(), whole);
    }

    #[test]
    fn backlog_estimate_counts_running_and_queued_work() {
        let cfg = ClusterConfig { num_cores: 2, policy: DispatchPolicy::Fifo };
        let services = [svc("m", 2, 10.0)];
        let mut chip = ChipSim::new(&cfg, &services, false).unwrap();
        assert_eq!(chip.waiting(), 0);
        assert_eq!(chip.backlog_ms(0.0), 0.0);
        // One running (dispatched on arrival), one queued behind it.
        chip.arrive(req(0, 0, 0.0));
        chip.arrive(req(1, 0, 0.0));
        assert_eq!(chip.waiting(), 1);
        // Running: 10 ms remaining on 2 cores; queued: 10 ms * 2 cores.
        // Normalized by the 2-core pool: 20 ms to drain.
        assert!((chip.backlog_ms(0.0) - 20.0).abs() < 1e-12);
        // Halfway through the running invocation the estimate shrinks.
        assert!((chip.backlog_ms(5.0) - 15.0).abs() < 1e-12);
        chip.advance(None);
    }
}
