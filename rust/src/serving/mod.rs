//! Multi-tenant serving: a deterministic discrete-event simulator over the
//! MLU core pool, a load-aware core allocator, and a multi-chip fleet
//! layer (rust/docs/DESIGN.md §9, §15).
//!
//! The paper's tuner optimizes *one* inference; the ROADMAP's north star is
//! serving heavy traffic. This module closes that gap:
//!
//! - [`workload`]: seeded arrival traces (closed-loop, open-loop Poisson,
//!   bursty) over weighted multi-model request mixes from the zoo;
//! - [`queue`] + [`cluster`]: an event-driven pool of
//!   [`crate::accel::AcceleratorSpec::num_cores`] cores where each request
//!   occupies its model's allocated MP for the `CostEngine`-predicted
//!   latency of its tuned schedule, under pluggable dispatch policies
//!   (FIFO, shortest-job-first, and dynamic batching — up to `max_batch`
//!   same-model requests ride one invocation priced by the engine's
//!   batch-aware model, held at most `max_wait_ms`; rust/docs/DESIGN.md
//!   §10) with per-model queues — driven through the [`SimulationRun`]
//!   builder;
//! - [`allocator`]: the [`AllocationRequest`] builder sweeps `(mp_cap,
//!   batch)` operating points per model through the constrained oracle DP
//!   (one shared cost-engine cache per model) and picks the
//!   throughput-optimal point under the offered load, reporting when it
//!   diverges from the single-request optimum;
//! - [`report`]: the SLO report — p50/p95/p99 end-to-end latency split
//!   into queueing vs service time, core utilization, and goodput under a
//!   deadline — built on the coordinator's [`crate::coordinator::metrics`]
//!   primitives;
//! - [`fleet`] + [`router`] + [`plan_cache`]: many chips behind one front
//!   door — heterogeneous [`Fleet`]s planned per chip kind through the
//!   fleet-wide tuned-[`PlanCache`], a deterministic routing layer
//!   (round-robin, least-loaded, model-sharded) with admission control,
//!   and the merged [`FleetReport`]/trace.
//!
//! Everything is a pure function of `(mix, process, seed, allocation,
//! fleet, routing)`: same seed ⇒ identical event trace and report. The
//! CLI front-ends are `dlfusion serve-sim` and `dlfusion serve-fleet`.
//!
//! ```no_run
//! use dlfusion::accel::{Simulator, Target};
//! use dlfusion::serving::{self, AllocationRequest, ArrivalProcess,
//!                         ClusterConfig, DispatchPolicy, ModelMix,
//!                         SimulationRun, SloReport};
//! use dlfusion::zoo;
//!
//! let sim = Simulator::new(Target::mlu100());
//! let mix = ModelMix::uniform(vec![zoo::resnet18(), zoo::alexnet()]);
//! let plan = AllocationRequest::new(&sim, &mix)
//!     .slo_ms(Some(50.0))
//!     .plan()
//!     .expect("plan");
//! let trace = serving::generate_trace(
//!     &mix, ArrivalProcess::OpenPoisson { rate_rps: 400.0 }, 1000, 7);
//! let cfg = ClusterConfig { num_cores: sim.spec.num_cores,
//!                           policy: DispatchPolicy::Fifo };
//! let result = SimulationRun::new(&cfg, &plan.services(true))
//!     .trace(&trace)
//!     .run()
//!     .expect("simulate");
//! println!("{}", SloReport::from_sim(&result, Some(50.0)).render());
//! ```

pub mod workload;
pub mod queue;
pub mod cluster;
pub mod allocator;
pub mod report;
pub mod plan_cache;
pub mod router;
pub mod fleet;

pub use allocator::{AllocationPlan, AllocationRequest, ModelAllocation,
                    OperatingPoint};
pub use cluster::{ClusterConfig, CompletedRequest, ModelService, SimEvent,
                  SimEventKind, SimResult, SimulationRun};
pub use fleet::{fleet_trace, plan_fleet, Chip, ChipPlan, ChipSummary, Fleet,
                FleetPlan, FleetReport, FleetResult, FleetRun, ShedEvent};
pub use plan_cache::{PlanCache, PlanCacheStats};
pub use queue::{DispatchPolicy, QueueSet, QueuedRequest, DEFAULT_BATCH_WAIT_MS,
                DEFAULT_MAX_BATCH};
pub use report::{sim_trace, ServingSeries, SloReport};
pub use router::{ChipLoad, RoutePolicy, Router, RouterConfig};
pub use workload::{generate_trace, ArrivalProcess, ModelMix, Request};

// The legacy free functions stay exported (and covered) until removal.
#[allow(deprecated)]
pub use allocator::{plan_allocations, plan_allocations_batched};
#[allow(deprecated)]
pub use cluster::{simulate, simulate_with};
