//! Fleet-scale serving (rust/docs/DESIGN.md §15): many chips, one front
//! door.
//!
//! PR 5's serving layer simulates one chip — a single core pool running a
//! tuned model mix. This module scales that picture out without changing
//! it: a [`Fleet`] is a list of named chips (each its own hardware
//! [`Target`] and pool width, heterogeneous mixes allowed), a [`Router`]
//! assigns every arriving request to one chip and optionally sheds under
//! overload, and each chip then runs the *exact* single-pool event loop
//! ([`super::cluster::ChipSim`]) it always ran. Placement is two-level:
//!
//! 1. **Per chip kind** — [`plan_fleet`] tunes the mix for each distinct
//!    hardware target through the fleet-wide [`PlanCache`], so each
//!    `(model, target, batch)` key is swept exactly once no matter how
//!    many chips share the kind.
//! 2. **Per model** — a greedy pass pins every model to its cheapest chip
//!    (descending traffic share, balancing predicted core-ms load), which
//!    becomes the `model-sharded` routing table.
//!
//! Determinism contract: routing and shedding are pure functions of the
//! trace and the chips' simulated state at each arrival instant, so a
//! fleet run — per-chip results, shed events, merged report, trace export
//! — is bit-identical run to run. A one-chip fleet with no queue cap
//! degenerates to the single-pool simulation exactly (pinned by
//! rust/tests/fleet_sim.rs).

use crate::accel::{Simulator, Target};
use crate::obs::{Domain, MetricsRegistry, TraceSession};
use crate::tuner::TuningError;
use crate::util::{Json, Table};

use super::allocator::AllocationPlan;
use super::cluster::{ChipSim, ClusterConfig, ModelService, SimResult};
use super::plan_cache::{PlanCache, PlanCacheStats};
use super::queue::DispatchPolicy;
use super::report::{ServingSeries, SloReport};
use super::router::{ChipLoad, Router, RouterConfig};
use super::workload::{ModelMix, Request};

/// One chip of the fleet: a hardware target plus its pool width.
#[derive(Debug, Clone, PartialEq)]
pub struct Chip {
    /// Fleet-unique name, `<target>-<index>` from the spec parser.
    pub name: String,
    pub target: Target,
    /// Pool width — the target's core count.
    pub num_cores: usize,
}

/// An ordered list of chips. Heterogeneous mixes are the point: PR 5's
/// single pool rejects mixed targets ([`crate::accel::TargetError`]'s
/// `MixedTargets`, still enforced *per chip*), while the fleet plans each
/// chip for its own hardware and balances across them.
#[derive(Debug, Clone, PartialEq)]
pub struct Fleet {
    pub chips: Vec<Chip>,
}

impl Fleet {
    /// Parse a fleet spec: comma-separated groups of `<target>x<count>`
    /// (or a bare `<target>` for one chip), e.g. `mlu100x2,edge4x4`.
    /// Chips are named `<target>-<i>` with `i` counting per target across
    /// the whole spec, so `mlu100,mlu100` and `mlu100x2` name identically.
    pub fn parse(spec: &str) -> Result<Fleet, String> {
        let mut chips = Vec::new();
        let mut seen: Vec<(String, usize)> = Vec::new();
        for group in spec.split(',') {
            let group = group.trim();
            if group.is_empty() {
                return Err(format!("fleet spec '{spec}': empty chip group"));
            }
            let (name, count) = match group.rsplit_once('x') {
                Some((name, n)) => match n.parse::<usize>() {
                    Ok(count) => (name, count),
                    Err(_) => (group, 1),
                },
                None => (group, 1),
            };
            if count == 0 {
                return Err(format!("chip group '{group}' asks for zero chips"));
            }
            let target = Target::by_name(name)
                .map_err(|e| format!("fleet spec '{spec}': {e}"))?;
            let start = match seen.iter_mut().find(|(t, _)| t == name) {
                Some((_, n)) => {
                    let start = *n;
                    *n += count;
                    start
                }
                None => {
                    seen.push((name.to_string(), count));
                    0
                }
            };
            for i in 0..count {
                chips.push(Chip {
                    name: format!("{name}-{}", start + i),
                    num_cores: target.spec().num_cores,
                    target: target.clone(),
                });
            }
        }
        Ok(Fleet { chips })
    }

    pub fn len(&self) -> usize {
        self.chips.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chips.is_empty()
    }

    /// Cores across every chip.
    pub fn total_cores(&self) -> usize {
        self.chips.iter().map(|c| c.num_cores).sum()
    }

    /// Distinct target names in first-appearance order — the set the plan
    /// cache actually tunes for.
    pub fn kinds(&self) -> Vec<&str> {
        let mut kinds: Vec<&str> = Vec::new();
        for c in &self.chips {
            if !kinds.contains(&c.target.name()) {
                kinds.push(c.target.name());
            }
        }
        kinds
    }
}

/// One chip's tuned slice of the fleet plan.
#[derive(Debug, Clone)]
pub struct ChipPlan {
    pub chip: Chip,
    /// The mix tuned for this chip's target (through the plan cache).
    pub plan: AllocationPlan,
    /// The services the chip's event loop simulates.
    pub services: Vec<ModelService>,
}

/// [`plan_fleet`]'s output: per-chip tuned plans, the level-1 model
/// placement, and what the plan cache saved building it.
#[derive(Debug, Clone)]
pub struct FleetPlan {
    pub slo_ms: Option<f64>,
    pub chips: Vec<ChipPlan>,
    /// Model index → chip index: the greedy placement, read by
    /// `model-sharded` routing.
    pub shard_of: Vec<usize>,
    /// Cache accounting for this planning call alone (a delta, not the
    /// cache's cumulative totals).
    pub cache_stats: PlanCacheStats,
}

impl FleetPlan {
    pub fn total_cores(&self) -> usize {
        self.chips.iter().map(|c| c.chip.num_cores).sum()
    }

    /// Predicted sustainable aggregate rate: the sum of every chip's
    /// single-pool capacity at the chosen operating points.
    pub fn predicted_capacity_rps(&self, load_aware: bool) -> f64 {
        self.chips
            .iter()
            .map(|c| c.plan.predicted_capacity_rps(c.chip.num_cores, load_aware))
            .sum()
    }

    /// Render the fleet table, the model placement, and the cache line.
    pub fn render(&self, load_aware: bool) -> String {
        let mut t = Table::new(&["chip", "target", "cores", "capacity"])
            .label_first()
            .with_title("fleet plan");
        for c in &self.chips {
            let cap = c.plan.predicted_capacity_rps(c.chip.num_cores, load_aware);
            t.row(vec![
                c.chip.name.clone(),
                c.chip.target.name().to_string(),
                c.chip.num_cores.to_string(),
                format!("{cap:.1} req/s"),
            ]);
        }
        let mut out = format!("{t}\n");
        for (m, &c) in self.shard_of.iter().enumerate() {
            let model = self.chips[c]
                .plan
                .models
                .get(m)
                .map_or("model", |a| a.name.as_str());
            out.push_str(&format!("{model} -> {}\n", self.chips[c].chip.name));
        }
        let s = self.cache_stats;
        out.push_str(&format!(
            "plan cache: {} tuned, {} reused ({} evals saved)\n",
            s.misses, s.hits, s.evals_saved));
        out
    }
}

/// Two-level fleet placement (rust/docs/DESIGN.md §15.1). Level 1 tunes
/// the mix once per chip *kind* through `cache`; level 2 greedily pins
/// each model (descending traffic share, ties by index) to the chip where
/// its predicted core-ms load lands cheapest, balancing per-core load.
/// The placement is advisory for `least-loaded`/`round-robin` routing and
/// binding for `model-sharded`.
pub fn plan_fleet(fleet: &Fleet, mix: &ModelMix, slo_ms: Option<f64>,
                  max_batch: usize, load_aware: bool, cache: &mut PlanCache)
                  -> Result<FleetPlan, TuningError> {
    if fleet.is_empty() {
        return Err(TuningError::InvalidRequest("fleet has no chips".into()));
    }
    let before = cache.stats();
    let mut chips = Vec::with_capacity(fleet.chips.len());
    for chip in &fleet.chips {
        let sim = Simulator::new(chip.target.clone());
        let plan = cache.plan_mix(&sim, mix, slo_ms, max_batch)?;
        let services = plan.services(load_aware);
        chips.push(ChipPlan { chip: chip.clone(), plan, services });
    }

    // Level 2: heaviest models place first; each lands on the chip whose
    // per-core load after taking it is smallest (strict `<`, so ties keep
    // the lowest chip index — deterministic).
    let mut order: Vec<usize> = (0..mix.models.len()).collect();
    order.sort_by(|&a, &b| {
        mix.share(b).total_cmp(&mix.share(a)).then(a.cmp(&b))
    });
    let mut load = vec![0.0f64; chips.len()];
    let mut shard_of = vec![0usize; mix.models.len()];
    for m in order {
        let mut best = 0usize;
        let mut best_load = f64::INFINITY;
        for (c, cp) in chips.iter().enumerate() {
            let alloc = &cp.plan.models[m];
            let per_req = if load_aware {
                alloc.load_aware.core_ms_at(alloc.load_aware_batch)
            } else {
                alloc.single.core_ms()
            };
            let taken =
                load[c] + mix.share(m) * per_req / cp.chip.num_cores as f64;
            if taken < best_load {
                best_load = taken;
                best = c;
            }
        }
        shard_of[m] = best;
        load[best] = best_load;
    }

    let after = cache.stats();
    let cache_stats = PlanCacheStats {
        hits: after.hits - before.hits,
        misses: after.misses - before.misses,
        evals_spent: after.evals_spent - before.evals_spent,
        evals_saved: after.evals_saved - before.evals_saved,
    };
    Ok(FleetPlan { slo_ms, chips, shard_of, cache_stats })
}

/// One request rejected by admission control: part of the deterministic
/// trace surface (shed events are pinned alongside the event log).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShedEvent {
    pub time_ms: f64,
    pub id: u64,
    pub model: usize,
    /// The chip the router picked before admission control rejected.
    pub chip: usize,
}

/// A fleet run's output: every chip's single-pool result plus the shed
/// log.
#[derive(Debug, Clone)]
pub struct FleetResult {
    pub per_chip: Vec<SimResult>,
    pub shed: Vec<ShedEvent>,
    /// Cores across the fleet (the merged view's pool width).
    pub total_cores: usize,
}

impl FleetResult {
    /// Requests completed across every chip.
    pub fn completed(&self) -> u64 {
        self.per_chip.iter().map(|r| r.completed.len() as u64).sum()
    }

    /// Requests the trace offered: completed plus shed (every validated
    /// request is exactly one of the two).
    pub fn offered(&self) -> u64 {
        self.completed() + self.shed.len() as u64
    }

    /// Fraction of offered requests shed.
    pub fn shed_rate(&self) -> f64 {
        let offered = self.offered();
        if offered == 0 {
            return 0.0;
        }
        self.shed.len() as f64 / offered as f64
    }

    /// Fold the per-chip results into one fleet-wide [`SimResult`]: events
    /// stably ordered by time (same-instant events keep chip order),
    /// completions by `(finish_ms, id)`, pool width = fleet cores. A
    /// one-chip fleet's merged view *is* the chip's own result — the
    /// single-pool parity pin.
    pub fn merged(&self) -> SimResult {
        if self.per_chip.len() == 1 {
            return self.per_chip[0].clone();
        }
        let mut events = Vec::new();
        let mut completed = Vec::new();
        let mut events_processed = 0;
        for r in &self.per_chip {
            events.extend(r.events.iter().copied());
            completed.extend(r.completed.iter().copied());
            events_processed += r.events_processed;
        }
        events.sort_by(|a, b| a.time_ms.total_cmp(&b.time_ms));
        completed.sort_by(|a, b| {
            a.finish_ms.total_cmp(&b.finish_ms).then(a.id.cmp(&b.id))
        });
        SimResult { events, completed, num_cores: self.total_cores,
                    events_processed }
    }
}

/// Builder for one fleet simulation — the fleet counterpart of
/// [`super::cluster::SimulationRun`].
///
/// Defaults: FIFO dispatch on every chip, events recorded. Fleet runs are
/// open-loop only (a closed loop has no meaningful fleet-wide
/// concurrency gate).
///
/// The run interleaves routing with simulation: for each trace request,
/// every chip advances to the arrival instant (so loads are exact, not
/// stale), the router picks a chip from those loads, and admission
/// control either injects the request or sheds it. See the module docs
/// for the determinism contract.
#[derive(Debug, Clone)]
pub struct FleetRun<'a> {
    plan: &'a FleetPlan,
    router: RouterConfig,
    policy: DispatchPolicy,
    trace: &'a [Request],
    record_events: bool,
}

impl<'a> FleetRun<'a> {
    pub fn new(plan: &'a FleetPlan, router: RouterConfig) -> FleetRun<'a> {
        FleetRun {
            plan,
            router,
            policy: DispatchPolicy::Fifo,
            trace: &[],
            record_events: true,
        }
    }

    /// Per-chip dispatch policy (every chip runs the same one).
    pub fn policy(mut self, policy: DispatchPolicy) -> FleetRun<'a> {
        self.policy = policy;
        self
    }

    /// The arrival trace, sorted by arrival time.
    pub fn trace(mut self, trace: &'a [Request]) -> FleetRun<'a> {
        self.trace = trace;
        self
    }

    /// Whether each chip keeps its full event log (default true).
    pub fn record_events(mut self, record_events: bool) -> FleetRun<'a> {
        self.record_events = record_events;
        self
    }

    /// Validate and run the fleet simulation.
    pub fn run(self) -> Result<FleetResult, String> {
        if self.plan.chips.is_empty() {
            return Err("fleet has no chips".into());
        }
        let n_models = self.plan.chips[0].services.len();
        let mut last = f64::NEG_INFINITY;
        for r in self.trace {
            if r.arrival_ms < last {
                return Err("trace must be sorted by arrival time".into());
            }
            last = r.arrival_ms;
            if r.model >= n_models {
                return Err(format!(
                    "request {} references model {} but only {} are planned",
                    r.id, r.model, n_models));
            }
        }
        let mut sims = Vec::with_capacity(self.plan.chips.len());
        for cp in &self.plan.chips {
            let cfg = ClusterConfig {
                num_cores: cp.chip.num_cores,
                policy: self.policy,
            };
            let sim = ChipSim::new(&cfg, &cp.services, self.record_events)
                .map_err(|e| format!("chip {}: {e}", cp.chip.name))?;
            sims.push(sim);
        }
        let mut router = Router::new(self.router, self.plan.shard_of.clone());
        let mut shed = Vec::new();
        for r in self.trace {
            // Advance every chip to the arrival instant first: completions
            // up to (and at) `arrival_ms` land before the router reads
            // loads, so the decision sees the exact simulated state.
            for sim in sims.iter_mut() {
                sim.advance(Some(r.arrival_ms));
            }
            let loads: Vec<ChipLoad> = sims
                .iter()
                .map(|s| ChipLoad {
                    waiting: s.waiting(),
                    backlog_ms: s.backlog_ms(r.arrival_ms),
                })
                .collect();
            let c = router.route(r.model, &loads);
            if router.sheds(loads[c].waiting) {
                shed.push(ShedEvent {
                    time_ms: r.arrival_ms,
                    id: r.id,
                    model: r.model,
                    chip: c,
                });
            } else {
                sims[c].arrive(*r);
            }
        }
        let total_cores = self.plan.total_cores();
        let mut per_chip = Vec::with_capacity(sims.len());
        for mut sim in sims {
            sim.advance(None);
            per_chip.push(sim.into_result());
        }
        Ok(FleetResult { per_chip, shed, total_cores })
    }
}

/// One chip's headline numbers in the fleet report.
#[derive(Debug, Clone)]
pub struct ChipSummary {
    pub name: String,
    pub requests: u64,
    pub throughput_rps: f64,
    pub utilization: f64,
}

/// The fleet report: the merged-run [`SloReport`] (with shed accounting)
/// plus a per-chip breakdown.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub slo: SloReport,
    pub chips: Vec<ChipSummary>,
    /// Core-milliseconds of fleet capacity spent per completed request —
    /// `total chip-cores × makespan / completed` (0 when nothing
    /// completed). The fleet-composition price tag ROADMAP item 5's
    /// capacity planning minimizes: a composition that meets the SLO with
    /// a lower `cost_per_request` retires the same traffic on less
    /// hardware-time.
    pub cost_per_request: f64,
}

impl FleetReport {
    pub fn from_run(result: &FleetResult, plan: &FleetPlan,
                    slo_ms: Option<f64>) -> FleetReport {
        let slo = SloReport::from_sim(&result.merged(), slo_ms)
            .with_shed(result.shed.len() as u64);
        let chips = result
            .per_chip
            .iter()
            .zip(&plan.chips)
            .map(|(r, cp)| ChipSummary {
                name: cp.chip.name.clone(),
                requests: r.completed.len() as u64,
                throughput_rps: r.throughput_rps(),
                utilization: r.utilization(),
            })
            .collect();
        let completed = result.completed();
        let cost_per_request = if completed == 0 {
            0.0
        } else {
            result.total_cores as f64 * slo.makespan_ms / completed as f64
        };
        FleetReport { slo, chips, cost_per_request }
    }

    /// The SLO table followed by the per-chip breakdown.
    pub fn render(&self) -> String {
        let mut out = self.slo.render();
        let mut t = Table::new(&["chip", "requests", "throughput", "util"])
            .label_first()
            .with_title("per-chip breakdown");
        for c in &self.chips {
            t.row(vec![
                c.name.clone(),
                c.requests.to_string(),
                format!("{:.1} req/s", c.throughput_rps),
                format!("{:.1}%", 100.0 * c.utilization),
            ]);
        }
        out.push_str(&format!("{t}\n"));
        out.push_str(&format!("cost per request: {:.3} core-ms\n",
                              self.cost_per_request));
        out
    }

    /// The merged [`SloReport`] export plus per-chip gauges
    /// (`serving.chip.<name>.*`).
    pub fn export_metrics(&self, reg: &mut MetricsRegistry) {
        self.slo.export_metrics(reg);
        reg.set_gauge(Domain::Sim, "serving.cost_per_request", self.cost_per_request);
        for c in &self.chips {
            reg.set_gauge(Domain::Sim,
                          &format!("serving.chip.{}.requests", c.name),
                          c.requests as f64);
            reg.set_gauge(Domain::Sim,
                          &format!("serving.chip.{}.throughput_rps", c.name),
                          c.throughput_rps);
            reg.set_gauge(Domain::Sim,
                          &format!("serving.chip.{}.utilization", c.name),
                          c.utilization);
        }
    }
}

/// Lanes reserved per chip in the fleet trace: chip `c`'s model `m` spans
/// render on track `c * LANES_PER_CHIP + m`.
const LANES_PER_CHIP: u64 = 64;

/// Build the fleet's sim-time trace: per chip, the same queue/serve spans
/// and queue-depth/utilization counter tracks as the single-pool
/// [`super::report::sim_trace`], on chip-prefixed names and per-chip
/// lanes; shed requests render as instant marks plus a cumulative
/// counter. Pure sim clock throughout, so the export is bit-identical run
/// to run.
pub fn fleet_trace(result: &FleetResult, plan: &FleetPlan,
                   name: &str) -> TraceSession {
    let mut tr = TraceSession::new(name);
    for (c, (r, cp)) in result.per_chip.iter().zip(&plan.chips).enumerate() {
        let chip = cp.chip.name.as_str();
        for done in &r.completed {
            let model = cp
                .services
                .get(done.model)
                .map_or("model", |s| s.name.as_str());
            let track = c as u64 * LANES_PER_CHIP + done.model as u64;
            if done.queue_ms() > 0.0 {
                tr.sim_span(&format!("{chip}/{model} queue"), "queue", track,
                            done.arrival_ms, done.start_ms,
                            vec![("id".to_string(), Json::Num(done.id as f64))]);
            }
            tr.sim_span(&format!("{chip}/{model} serve"), "service", track,
                        done.start_ms, done.finish_ms,
                        vec![
                            ("id".to_string(), Json::Num(done.id as f64)),
                            ("cores".to_string(), Json::Num(done.cores as f64)),
                            ("batch".to_string(), Json::Num(done.batch as f64)),
                        ]);
        }
        let series = ServingSeries::from_sim(r);
        for (t, d) in series.queue_time_ms.iter().zip(&series.queue_depth) {
            tr.sim_counter(&format!("{chip} queue depth"), *t, *d as f64);
        }
        for (b, u) in series.utilization.iter().enumerate() {
            tr.sim_counter(&format!("{chip} core utilization"),
                           b as f64 * series.util_bucket_ms, *u);
        }
    }
    for (i, s) in result.shed.iter().enumerate() {
        tr.sim_instant(&format!("shed #{}", s.id), "shed",
                       s.chip as u64 * LANES_PER_CHIP, s.time_ms);
        tr.sim_counter("shed requests", s.time_ms, (i + 1) as f64);
    }
    tr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::cluster::CompletedRequest;

    #[test]
    fn parse_names_chips_per_target() {
        let fleet = Fleet::parse("mlu100x2,edge4x4").unwrap();
        assert_eq!(fleet.len(), 6);
        let names: Vec<&str> =
            fleet.chips.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(names, vec!["mlu100-0", "mlu100-1", "edge4-0", "edge4-1",
                               "edge4-2", "edge4-3"]);
        assert_eq!(fleet.kinds(), vec!["mlu100", "edge4"]);
        assert_eq!(fleet.total_cores(), 2 * 32 + 4 * 4);
        // A bare target is one chip; repeated groups keep counting.
        let again = Fleet::parse("edge4,edge4x2").unwrap();
        let names: Vec<&str> =
            again.chips.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(names, vec!["edge4-0", "edge4-1", "edge4-2"]);
        assert_eq!(again.kinds(), vec!["edge4"]);
    }

    #[test]
    fn parse_rejects_bad_specs() {
        let err = Fleet::parse("mlu100x2,").unwrap_err();
        assert!(err.contains("empty chip group"), "{err}");
        let err = Fleet::parse("mlu100x0").unwrap_err();
        assert!(err.contains("zero chips"), "{err}");
        let err = Fleet::parse("tpu9000x2").unwrap_err();
        assert!(err.contains("unknown target"), "{err}");
        assert!(err.contains("fleet spec"), "{err}");
    }

    fn done(id: u64, finish_ms: f64) -> CompletedRequest {
        CompletedRequest { id, model: 0, arrival_ms: 0.0, start_ms: 0.0,
                           finish_ms, cores: 1, batch: 1 }
    }

    fn chip_result(completed: Vec<CompletedRequest>, num_cores: usize)
                   -> SimResult {
        SimResult { events: Vec::new(), completed, num_cores,
                    events_processed: 0 }
    }

    #[test]
    fn merged_single_chip_is_the_chip_result() {
        let r = chip_result(vec![done(1, 8.0), done(0, 8.0)], 4);
        let fr = FleetResult { per_chip: vec![r.clone()], shed: Vec::new(),
                               total_cores: 4 };
        // Identity — even for same-instant completions the single-pool
        // order is preserved verbatim.
        assert_eq!(fr.merged(), r);
    }

    #[test]
    fn merged_interleaves_chips_deterministically() {
        let a = chip_result(vec![done(0, 5.0), done(2, 9.0)], 4);
        let b = chip_result(vec![done(1, 5.0), done(3, 7.0)], 2);
        let fr = FleetResult {
            per_chip: vec![a, b],
            shed: vec![ShedEvent { time_ms: 1.0, id: 9, model: 0, chip: 1 }],
            total_cores: 6,
        };
        assert_eq!(fr.completed(), 4);
        assert_eq!(fr.offered(), 5);
        assert!((fr.shed_rate() - 0.2).abs() < 1e-12);
        let merged = fr.merged();
        assert_eq!(merged.num_cores, 6);
        let ids: Vec<u64> = merged.completed.iter().map(|c| c.id).collect();
        // finish order, same-instant ties by id.
        assert_eq!(ids, vec![0, 1, 3, 2]);
    }
}
