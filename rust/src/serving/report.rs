//! The SLO report over one simulation run (rust/docs/DESIGN.md §9.4).
//!
//! Reuses the coordinator's metric primitives — [`LatencyRecorder`] (its
//! batch [`LatencyRecorder::percentiles`] accessor sorts once for all three
//! tail points) and [`Counters`] — to split end-to-end latency into
//! queueing vs service time and report utilization, throughput, and goodput
//! under a deadline.

use crate::coordinator::metrics::{Counters, LatencyRecorder};
use crate::util::Table;

use super::cluster::SimResult;

/// SLO-oriented summary of a [`SimResult`].
#[derive(Debug, Clone)]
pub struct SloReport {
    pub slo_ms: Option<f64>,
    /// End-to-end latency samples (arrival → finish), ms.
    pub e2e: LatencyRecorder,
    /// Queueing-delay samples (arrival → start), ms.
    pub queueing: LatencyRecorder,
    /// Service-time samples (start → finish), ms.
    pub service: LatencyRecorder,
    pub counters: Counters,
    /// Core-time fraction spent serving.
    pub utilization: f64,
    /// Completions per second of simulated time.
    pub throughput_rps: f64,
    /// SLO-met completions per second of simulated time (equals
    /// `throughput_rps` when no SLO is set).
    pub goodput_rps: f64,
    pub makespan_ms: f64,
}

impl SloReport {
    /// Fold a simulation run into the report.
    pub fn from_sim(result: &SimResult, slo_ms: Option<f64>) -> SloReport {
        let mut e2e = LatencyRecorder::new();
        let mut queueing = LatencyRecorder::new();
        let mut service = LatencyRecorder::new();
        let mut counters = Counters::new();
        let mut within = 0u64;
        // Core reservations: a batched invocation reserves its cores once
        // for the whole batch, so each rider contributes its 1/batch share
        // (integral — and identical to the pre-batch count — when every
        // batch is 1).
        let mut core_launches = 0.0;
        for c in &result.completed {
            e2e.record(c.e2e_ms());
            queueing.record(c.queue_ms());
            service.record(c.service_ms());
            counters.inc("requests");
            core_launches += c.cores as f64 / c.batch as f64;
            if let Some(slo) = slo_ms {
                if c.e2e_ms() <= slo {
                    within += 1;
                    counters.inc("slo_ok");
                } else {
                    counters.inc("slo_violations");
                }
            }
        }
        counters.add("core_launches", core_launches.round() as u64);
        let makespan_ms = result.makespan_ms();
        let throughput_rps = result.throughput_rps();
        let goodput_rps = match slo_ms {
            None => throughput_rps,
            Some(_) if makespan_ms > 0.0 => within as f64 / (makespan_ms / 1000.0),
            Some(_) => 0.0,
        };
        SloReport {
            slo_ms,
            e2e,
            queueing,
            service,
            counters,
            utilization: result.utilization(),
            throughput_rps,
            goodput_rps,
            makespan_ms,
        }
    }

    /// Fraction of completed requests that met the SLO (1.0 with no SLO).
    pub fn slo_attainment(&self) -> f64 {
        let total = self.counters.get("requests");
        if self.slo_ms.is_none() || total == 0 {
            return 1.0;
        }
        self.counters.get("slo_ok") as f64 / total as f64
    }

    /// Render the report table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&["metric", "value"])
            .label_first()
            .with_title("serving SLO report");
        let n = self.e2e.count();
        t.row(vec!["requests completed".into(), n.to_string()]);
        t.row(vec!["makespan".into(), format!("{:.2} ms", self.makespan_ms)]);
        t.row(vec!["throughput".into(),
                   format!("{:.1} req/s", self.throughput_rps)]);
        match self.slo_ms {
            Some(slo) => {
                t.row(vec![format!("goodput (SLO {slo} ms)"),
                           format!("{:.1} req/s", self.goodput_rps)]);
                t.row(vec!["SLO attainment".into(),
                           format!("{:.1}%", 100.0 * self.slo_attainment())]);
            }
            None => {
                t.row(vec!["goodput".into(),
                           format!("{:.1} req/s (no SLO)", self.goodput_rps)]);
            }
        }
        t.row(vec!["core utilization".into(),
                   format!("{:.1}%", 100.0 * self.utilization)]);
        if let Some(ps) = self.e2e.percentiles(&[50.0, 95.0, 99.0]) {
            t.row(vec!["e2e p50/p95/p99".into(),
                       format!("{:.2} / {:.2} / {:.2} ms", ps[0], ps[1], ps[2])]);
        }
        if let (Some(q), Some(s)) = (self.queueing.summary(), self.service.summary()) {
            t.row(vec!["mean queueing".into(), format!("{:.2} ms", q.mean)]);
            t.row(vec!["mean service".into(), format!("{:.2} ms", s.mean)]);
            t.row(vec!["max queueing".into(), format!("{:.2} ms", q.max)]);
        }
        format!("{t}\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::cluster::{CompletedRequest, SimResult};

    fn result() -> SimResult {
        let completed = vec![
            CompletedRequest { id: 0, model: 0, arrival_ms: 0.0, start_ms: 0.0,
                               finish_ms: 10.0, cores: 2, batch: 1 },
            CompletedRequest { id: 1, model: 0, arrival_ms: 0.0, start_ms: 10.0,
                               finish_ms: 20.0, cores: 2, batch: 1 },
            CompletedRequest { id: 2, model: 0, arrival_ms: 5.0, start_ms: 20.0,
                               finish_ms: 30.0, cores: 2, batch: 1 },
        ];
        SimResult { events: Vec::new(), completed, num_cores: 2,
                    events_processed: 0 }
    }

    #[test]
    fn splits_queueing_from_service() {
        let rep = SloReport::from_sim(&result(), None);
        assert_eq!(rep.e2e.count(), 3);
        let q = rep.queueing.summary().unwrap();
        let s = rep.service.summary().unwrap();
        assert!((q.mean - (0.0 + 10.0 + 15.0) / 3.0).abs() < 1e-12);
        assert!((s.mean - 10.0).abs() < 1e-12);
        // 60 busy core-ms on a 2-core pool over 30 ms.
        assert!((rep.utilization - 1.0).abs() < 1e-12);
        assert!((rep.throughput_rps - 100.0).abs() < 1e-9);
        assert_eq!(rep.goodput_rps, rep.throughput_rps);
        assert_eq!(rep.slo_attainment(), 1.0);
    }

    #[test]
    fn goodput_counts_only_slo_met_requests() {
        // e2e latencies: 10, 20, 25 ms. SLO 15 ms -> 1 of 3 within.
        let rep = SloReport::from_sim(&result(), Some(15.0));
        assert_eq!(rep.counters.get("slo_ok"), 1);
        assert_eq!(rep.counters.get("slo_violations"), 2);
        assert!((rep.slo_attainment() - 1.0 / 3.0).abs() < 1e-12);
        // 1 good request over 30 ms.
        assert!((rep.goodput_rps - 1000.0 / 30.0).abs() < 1e-9);
        assert!(rep.goodput_rps < rep.throughput_rps);
    }

    #[test]
    fn render_contains_the_headline_metrics() {
        let rep = SloReport::from_sim(&result(), Some(15.0));
        let text = rep.render();
        for needle in ["throughput", "goodput", "SLO attainment",
                       "e2e p50/p95/p99", "core utilization"] {
            assert!(text.contains(needle), "missing {needle}: {text}");
        }
    }

    #[test]
    fn empty_run_reports_zeroes() {
        let empty = SimResult { events: Vec::new(), completed: Vec::new(),
                                num_cores: 4, events_processed: 0 };
        let rep = SloReport::from_sim(&empty, Some(10.0));
        assert_eq!(rep.e2e.count(), 0);
        assert_eq!(rep.throughput_rps, 0.0);
        assert_eq!(rep.goodput_rps, 0.0);
        assert_eq!(rep.slo_attainment(), 1.0);
        assert!(rep.render().contains("requests completed"));
    }
}
