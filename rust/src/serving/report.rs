//! The SLO report over one simulation run (rust/docs/DESIGN.md §9.4).
//!
//! Reuses the coordinator's metric primitives — [`LatencyRecorder`] (its
//! batch [`LatencyRecorder::percentiles`] accessor sorts once for all three
//! tail points) and [`Counters`] — to split end-to-end latency into
//! queueing vs service time and report utilization, throughput, and goodput
//! under a deadline.

use crate::coordinator::metrics::{Counters, LatencyRecorder};
use crate::obs::{Domain, MetricsRegistry, TraceSession};
use crate::util::{Json, Table};

use super::cluster::{ModelService, SimEventKind, SimResult};

/// Utilization buckets per run (the series is a report/trace aid, not a
/// raw log, so it stays small regardless of trace length).
const UTIL_BUCKETS: usize = 64;

/// Queue-depth samples kept after deterministic downsampling.
const MAX_QUEUE_SAMPLES: usize = 256;

/// Time-series view of one simulation run (rust/docs/DESIGN.md §14):
/// event-sampled queue depth plus fixed-bucket core utilization. Both are
/// pure functions of the (deterministic) simulation, so metrics snapshots
/// and trace exports built from them are bit-identical run to run and
/// across `--threads` counts.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServingSeries {
    /// Sample times, simulated ms — one entry per Arrive/Start event
    /// (empty when the run recorded no events).
    pub queue_time_ms: Vec<f64>,
    /// Requests waiting (arrived, not yet started) after each sample.
    pub queue_depth: Vec<u64>,
    /// Width of one utilization bucket, ms (0 when the run is empty).
    pub util_bucket_ms: f64,
    /// Busy-core fraction per bucket over `[0, makespan)`.
    pub utilization: Vec<f64>,
}

impl ServingSeries {
    /// Replay a run into the series. Queue depth comes from the event log
    /// (each `Arrive` is one waiting rider, each `Start` seats one);
    /// utilization comes from the completion records, where each rider
    /// carries its `cores / batch` share of the invocation's reservation —
    /// an invocation's riders sum back to exactly its reserved cores.
    pub fn from_sim(result: &SimResult) -> ServingSeries {
        let mut s = ServingSeries::default();
        let mut waiting: u64 = 0;
        for e in &result.events {
            match e.kind {
                SimEventKind::Arrive { .. } => waiting += 1,
                SimEventKind::Start { .. } => waiting = waiting.saturating_sub(1),
                SimEventKind::Finish { .. } => continue,
            }
            s.queue_time_ms.push(e.time_ms);
            s.queue_depth.push(waiting);
        }
        s.downsample_queue(MAX_QUEUE_SAMPLES);
        let makespan = result.makespan_ms();
        if makespan > 0.0 && !result.completed.is_empty() {
            let bucket = makespan / UTIL_BUCKETS as f64;
            let mut busy_ms = vec![0.0; UTIL_BUCKETS];
            for c in &result.completed {
                let weight = c.cores as f64 / c.batch as f64;
                for (b, acc) in busy_ms.iter_mut().enumerate() {
                    let lo = b as f64 * bucket;
                    let overlap =
                        (c.finish_ms.min(lo + bucket) - c.start_ms.max(lo)).max(0.0);
                    *acc += weight * overlap;
                }
            }
            s.util_bucket_ms = bucket;
            s.utilization = busy_ms
                .into_iter()
                .map(|b| b / (result.num_cores as f64 * bucket))
                .collect();
        }
        s
    }

    /// Deterministic decimation: keep every `ceil(n / cap)`-th sample plus
    /// the final one, so reruns agree sample for sample.
    fn downsample_queue(&mut self, cap: usize) {
        let n = self.queue_time_ms.len();
        if n <= cap {
            return;
        }
        let stride = n.div_ceil(cap);
        let mut keep: Vec<usize> = (0..n).step_by(stride).collect();
        if *keep.last().unwrap() != n - 1 {
            keep.push(n - 1);
        }
        self.queue_time_ms = keep.iter().map(|&i| self.queue_time_ms[i]).collect();
        self.queue_depth = keep.iter().map(|&i| self.queue_depth[i]).collect();
    }

    /// Highest sampled queue depth (0 when no events were recorded).
    pub fn peak_queue_depth(&self) -> u64 {
        self.queue_depth.iter().copied().max().unwrap_or(0)
    }

    /// Highest bucket utilization (0 when the run is empty).
    pub fn peak_utilization(&self) -> f64 {
        self.utilization.iter().copied().fold(0.0, f64::max)
    }
}

/// Build the sim-time trace of a recorded run: one lane (`tid`) per model
/// with a queue span (when the request waited) and a service span per
/// completed request, plus queue-depth and core-utilization counter
/// tracks. Every event is on the sim clock, so the Chrome trace-event
/// export is bit-identical run to run and across `--threads` counts
/// (pinned by rust/tests/parallel_parity.rs). Requires the simulation to
/// have recorded events for the queue-depth track; spans need only the
/// completion records.
pub fn sim_trace(result: &SimResult, services: &[ModelService],
                 name: &str) -> TraceSession {
    let mut tr = TraceSession::new(name);
    for c in &result.completed {
        let model = services.get(c.model).map_or("model", |s| s.name.as_str());
        if c.queue_ms() > 0.0 {
            tr.sim_span(&format!("{model} queue"), "queue", c.model as u64,
                        c.arrival_ms, c.start_ms,
                        vec![("id".to_string(), Json::Num(c.id as f64))]);
        }
        tr.sim_span(&format!("{model} serve"), "service", c.model as u64,
                    c.start_ms, c.finish_ms,
                    vec![
                        ("id".to_string(), Json::Num(c.id as f64)),
                        ("cores".to_string(), Json::Num(c.cores as f64)),
                        ("batch".to_string(), Json::Num(c.batch as f64)),
                    ]);
    }
    let series = ServingSeries::from_sim(result);
    for (t, d) in series.queue_time_ms.iter().zip(&series.queue_depth) {
        tr.sim_counter("queue depth", *t, *d as f64);
    }
    for (b, u) in series.utilization.iter().enumerate() {
        tr.sim_counter("core utilization", b as f64 * series.util_bucket_ms, *u);
    }
    tr
}

/// SLO-oriented summary of a [`SimResult`].
#[derive(Debug, Clone)]
pub struct SloReport {
    pub slo_ms: Option<f64>,
    /// End-to-end latency samples (arrival → finish), ms.
    pub e2e: LatencyRecorder,
    /// Queueing-delay samples (arrival → start), ms.
    pub queueing: LatencyRecorder,
    /// Service-time samples (start → finish), ms.
    pub service: LatencyRecorder,
    pub counters: Counters,
    /// Core-time fraction spent serving.
    pub utilization: f64,
    /// Completions per second of simulated time.
    pub throughput_rps: f64,
    /// SLO-met completions per second of simulated time (equals
    /// `throughput_rps` when no SLO is set).
    pub goodput_rps: f64,
    pub makespan_ms: f64,
    /// Requests rejected by fleet admission control before queueing
    /// (rust/docs/DESIGN.md §15.2) — 0 for single-pool runs, attached via
    /// [`Self::with_shed`] by the fleet path. A zero-shed report renders
    /// and exports byte-identically to the pre-fleet shape, which is what
    /// pins the one-chip fleet to `serve-sim`.
    pub shed: u64,
    /// Queue-depth / utilization time series replayed from the run.
    pub series: ServingSeries,
}

impl SloReport {
    /// Fold a simulation run into the report.
    pub fn from_sim(result: &SimResult, slo_ms: Option<f64>) -> SloReport {
        let mut e2e = LatencyRecorder::new();
        let mut queueing = LatencyRecorder::new();
        let mut service = LatencyRecorder::new();
        let mut counters = Counters::new();
        let mut within = 0u64;
        // Core reservations: a batched invocation reserves its cores once
        // for the whole batch, so each rider contributes its 1/batch share
        // (integral — and identical to the pre-batch count — when every
        // batch is 1).
        let mut core_launches = 0.0;
        for c in &result.completed {
            e2e.record(c.e2e_ms());
            queueing.record(c.queue_ms());
            service.record(c.service_ms());
            counters.inc("requests");
            core_launches += c.cores as f64 / c.batch as f64;
            if let Some(slo) = slo_ms {
                if c.e2e_ms() <= slo {
                    within += 1;
                    counters.inc("slo_ok");
                } else {
                    counters.inc("slo_violations");
                }
            }
        }
        counters.add("core_launches", core_launches.round() as u64);
        let makespan_ms = result.makespan_ms();
        let throughput_rps = result.throughput_rps();
        let goodput_rps = match slo_ms {
            None => throughput_rps,
            Some(_) if makespan_ms > 0.0 => within as f64 / (makespan_ms / 1000.0),
            Some(_) => 0.0,
        };
        SloReport {
            slo_ms,
            e2e,
            queueing,
            service,
            counters,
            utilization: result.utilization(),
            throughput_rps,
            goodput_rps,
            makespan_ms,
            shed: 0,
            series: ServingSeries::from_sim(result),
        }
    }

    /// Attach fleet admission-control accounting: `shed` requests were
    /// rejected before queueing, so they appear in no completion record.
    /// With `shed > 0` the report gains a `shed` counter and a shed-rate
    /// row/gauge; with `shed = 0` it stays byte-identical to
    /// [`Self::from_sim`]'s output.
    pub fn with_shed(mut self, shed: u64) -> SloReport {
        self.shed = shed;
        if shed > 0 {
            self.counters.add("shed", shed);
        }
        self
    }

    /// Fraction of offered requests (completed + shed) rejected by
    /// admission control.
    pub fn shed_rate(&self) -> f64 {
        let offered = self.counters.get("requests") + self.shed;
        if offered == 0 {
            return 0.0;
        }
        self.shed as f64 / offered as f64
    }

    /// Export the report into the unified registry (rust/docs/DESIGN.md
    /// §14). Everything here is simulated-time derived — [`Domain::Sim`]
    /// throughout — so snapshots are bit-identical run to run.
    pub fn export_metrics(&self, reg: &mut MetricsRegistry) {
        reg.set_gauge(Domain::Sim, "serving.throughput_rps", self.throughput_rps);
        reg.set_gauge(Domain::Sim, "serving.goodput_rps", self.goodput_rps);
        reg.set_gauge(Domain::Sim, "serving.utilization", self.utilization);
        reg.set_gauge(Domain::Sim, "serving.makespan_ms", self.makespan_ms);
        reg.set_gauge(Domain::Sim, "serving.slo_attainment", self.slo_attainment());
        if self.shed > 0 {
            reg.set_gauge(Domain::Sim, "serving.shed_rate", self.shed_rate());
        }
        self.counters.export_metrics(reg, Domain::Sim, "serving.");
        self.e2e.export_metrics(reg, Domain::Sim, "serving.e2e.");
        self.queueing.export_metrics(reg, Domain::Sim, "serving.queueing.");
        self.service.export_metrics(reg, Domain::Sim, "serving.service.");
        if !self.series.queue_depth.is_empty() {
            reg.set_gauge(Domain::Sim, "serving.peak_queue_depth",
                          self.series.peak_queue_depth() as f64);
            for &d in &self.series.queue_depth {
                reg.observe(Domain::Sim, "serving.queue_depth", d as f64);
            }
        }
        if !self.series.utilization.is_empty() {
            reg.set_gauge(Domain::Sim, "serving.peak_utilization",
                          self.series.peak_utilization());
        }
    }

    /// Fraction of completed requests that met the SLO (1.0 with no SLO).
    pub fn slo_attainment(&self) -> f64 {
        let total = self.counters.get("requests");
        if self.slo_ms.is_none() || total == 0 {
            return 1.0;
        }
        self.counters.get("slo_ok") as f64 / total as f64
    }

    /// Render the report table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&["metric", "value"])
            .label_first()
            .with_title("serving SLO report");
        let n = self.e2e.count();
        t.row(vec!["requests completed".into(), n.to_string()]);
        if self.shed > 0 {
            t.row(vec!["requests shed".into(),
                       format!("{} ({:.1}%)", self.shed,
                               100.0 * self.shed_rate())]);
        }
        t.row(vec!["makespan".into(), format!("{:.2} ms", self.makespan_ms)]);
        t.row(vec!["throughput".into(),
                   format!("{:.1} req/s", self.throughput_rps)]);
        match self.slo_ms {
            Some(slo) => {
                t.row(vec![format!("goodput (SLO {slo} ms)"),
                           format!("{:.1} req/s", self.goodput_rps)]);
                t.row(vec!["SLO attainment".into(),
                           format!("{:.1}%", 100.0 * self.slo_attainment())]);
            }
            None => {
                t.row(vec!["goodput".into(),
                           format!("{:.1} req/s (no SLO)", self.goodput_rps)]);
            }
        }
        t.row(vec!["core utilization".into(),
                   format!("{:.1}%", 100.0 * self.utilization)]);
        if !self.series.queue_depth.is_empty() {
            t.row(vec!["peak queue depth".into(),
                       self.series.peak_queue_depth().to_string()]);
        }
        if !self.series.utilization.is_empty() {
            t.row(vec!["peak bucket utilization".into(),
                       format!("{:.1}%", 100.0 * self.series.peak_utilization())]);
        }
        if let Some(ps) = self.e2e.percentiles(&[50.0, 95.0, 99.0]) {
            t.row(vec!["e2e p50/p95/p99".into(),
                       format!("{:.2} / {:.2} / {:.2} ms", ps[0], ps[1], ps[2])]);
        }
        if let (Some(q), Some(s)) = (self.queueing.summary(), self.service.summary()) {
            t.row(vec!["mean queueing".into(), format!("{:.2} ms", q.mean)]);
            t.row(vec!["mean service".into(), format!("{:.2} ms", s.mean)]);
            t.row(vec!["max queueing".into(), format!("{:.2} ms", q.max)]);
        }
        format!("{t}\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::cluster::{CompletedRequest, SimEvent, SimResult};

    fn result() -> SimResult {
        let completed = vec![
            CompletedRequest { id: 0, model: 0, arrival_ms: 0.0, start_ms: 0.0,
                               finish_ms: 10.0, cores: 2, batch: 1 },
            CompletedRequest { id: 1, model: 0, arrival_ms: 0.0, start_ms: 10.0,
                               finish_ms: 20.0, cores: 2, batch: 1 },
            CompletedRequest { id: 2, model: 0, arrival_ms: 5.0, start_ms: 20.0,
                               finish_ms: 30.0, cores: 2, batch: 1 },
        ];
        SimResult { events: Vec::new(), completed, num_cores: 2,
                    events_processed: 0 }
    }

    fn result_with_events() -> SimResult {
        let mut r = result();
        r.events = vec![
            SimEvent { time_ms: 0.0,
                       kind: SimEventKind::Arrive { id: 0, model: 0 } },
            SimEvent { time_ms: 0.0,
                       kind: SimEventKind::Arrive { id: 1, model: 0 } },
            SimEvent { time_ms: 0.0,
                       kind: SimEventKind::Start { id: 0, cores: 2 } },
            SimEvent { time_ms: 5.0,
                       kind: SimEventKind::Arrive { id: 2, model: 0 } },
            SimEvent { time_ms: 10.0,
                       kind: SimEventKind::Finish { id: 0, free_cores: 2 } },
            SimEvent { time_ms: 10.0,
                       kind: SimEventKind::Start { id: 1, cores: 2 } },
            SimEvent { time_ms: 20.0,
                       kind: SimEventKind::Finish { id: 1, free_cores: 2 } },
            SimEvent { time_ms: 20.0,
                       kind: SimEventKind::Start { id: 2, cores: 2 } },
            SimEvent { time_ms: 30.0,
                       kind: SimEventKind::Finish { id: 2, free_cores: 2 } },
        ];
        r.events_processed = r.events.len() as u64;
        r
    }

    #[test]
    fn series_replays_queue_depth_and_buckets_utilization() {
        let r = result_with_events();
        let s = ServingSeries::from_sim(&r);
        // Arrive, Arrive, Start, Arrive, Start, Start — Finish is skipped.
        assert_eq!(s.queue_depth, vec![1, 2, 1, 2, 1, 0]);
        assert_eq!(s.queue_time_ms, vec![0.0, 0.0, 0.0, 5.0, 10.0, 20.0]);
        assert_eq!(s.peak_queue_depth(), 2);
        // Back-to-back full-width invocations: every bucket fully busy.
        assert_eq!(s.utilization.len(), 64);
        assert!(s.utilization.iter().all(|&u| (u - 1.0).abs() < 1e-9),
                "{:?}", s.utilization);
        assert!((s.peak_utilization() - 1.0).abs() < 1e-9);
        // Bucket mean agrees with the run's aggregate utilization.
        let mean = s.utilization.iter().sum::<f64>() / s.utilization.len() as f64;
        assert!((mean - r.utilization()).abs() < 1e-9);
    }

    #[test]
    fn series_downsamples_deterministically() {
        let mut r = result_with_events();
        // Inflate the log past the sample cap with arrive/start pairs.
        for i in 0..2000u64 {
            r.events.push(SimEvent {
                time_ms: 30.0 + i as f64,
                kind: SimEventKind::Arrive { id: 100 + i, model: 0 },
            });
        }
        let a = ServingSeries::from_sim(&r);
        let b = ServingSeries::from_sim(&r);
        assert_eq!(a, b);
        assert!(a.queue_depth.len() <= MAX_QUEUE_SAMPLES + 1,
                "{}", a.queue_depth.len());
        // The final sample is always kept.
        assert_eq!(*a.queue_time_ms.last().unwrap(), 30.0 + 1999.0);
    }

    #[test]
    fn report_exports_sim_domain_metrics() {
        let rep = SloReport::from_sim(&result_with_events(), Some(15.0));
        let mut reg = MetricsRegistry::new();
        rep.export_metrics(&mut reg);
        assert_eq!(reg.gauge("serving.throughput_rps"), Some(rep.throughput_rps));
        assert_eq!(reg.gauge("serving.peak_queue_depth"), Some(2.0));
        assert_eq!(reg.counter("serving.slo_ok"), Some(1));
        assert_eq!(reg.gauge("serving.e2e.p50_ms"), rep.e2e.percentile(50.0));
        let h = reg.histogram("serving.queue_depth").unwrap();
        assert_eq!(h.count(), rep.series.queue_depth.len() as u64);
        // Everything lands in the deterministic section.
        let snap = reg.snapshot();
        assert!(snap.get("wall").as_obj().unwrap().is_empty());
    }

    #[test]
    fn sim_trace_is_deterministic_and_pure_sim_time() {
        let r = result_with_events();
        let services = [ModelService::new("m", 2, 10.0)];
        let a = sim_trace(&r, &services, "serve-sim");
        let b = sim_trace(&r, &services, "serve-sim");
        assert_eq!(a.to_chrome_string(), b.to_chrome_string());
        let doc = a.to_chrome_json();
        let events = doc.get("traceEvents").as_arr().unwrap();
        // 3 service spans + 2 queue spans (request 0 never waited) + one
        // metadata record + counter samples.
        let spans = events.iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .count();
        assert_eq!(spans, 5);
        // Pure sim clock: every non-metadata event sits in pid 1.
        assert!(events.iter()
            .filter(|e| e.get("ph").as_str() != Some("M"))
            .all(|e| e.get("pid").as_f64() == Some(1.0)));
        assert!(events.iter()
            .any(|e| e.get("name").as_str() == Some("queue depth")));
        assert!(events.iter()
            .any(|e| e.get("name").as_str() == Some("core utilization")));
    }

    #[test]
    fn splits_queueing_from_service() {
        let rep = SloReport::from_sim(&result(), None);
        assert_eq!(rep.e2e.count(), 3);
        let q = rep.queueing.summary().unwrap();
        let s = rep.service.summary().unwrap();
        assert!((q.mean - (0.0 + 10.0 + 15.0) / 3.0).abs() < 1e-12);
        assert!((s.mean - 10.0).abs() < 1e-12);
        // 60 busy core-ms on a 2-core pool over 30 ms.
        assert!((rep.utilization - 1.0).abs() < 1e-12);
        assert!((rep.throughput_rps - 100.0).abs() < 1e-9);
        assert_eq!(rep.goodput_rps, rep.throughput_rps);
        assert_eq!(rep.slo_attainment(), 1.0);
    }

    #[test]
    fn goodput_counts_only_slo_met_requests() {
        // e2e latencies: 10, 20, 25 ms. SLO 15 ms -> 1 of 3 within.
        let rep = SloReport::from_sim(&result(), Some(15.0));
        assert_eq!(rep.counters.get("slo_ok"), 1);
        assert_eq!(rep.counters.get("slo_violations"), 2);
        assert!((rep.slo_attainment() - 1.0 / 3.0).abs() < 1e-12);
        // 1 good request over 30 ms.
        assert!((rep.goodput_rps - 1000.0 / 30.0).abs() < 1e-9);
        assert!(rep.goodput_rps < rep.throughput_rps);
    }

    #[test]
    fn render_contains_the_headline_metrics() {
        let rep = SloReport::from_sim(&result(), Some(15.0));
        let text = rep.render();
        for needle in ["throughput", "goodput", "SLO attainment",
                       "e2e p50/p95/p99", "core utilization"] {
            assert!(text.contains(needle), "missing {needle}: {text}");
        }
    }

    #[test]
    fn shed_accounting_is_opt_in_and_zero_is_invisible() {
        let base = SloReport::from_sim(&result(), Some(15.0));
        // Zero shed leaves the report byte-identical — the one-chip fleet
        // parity pin depends on this.
        let zero = SloReport::from_sim(&result(), Some(15.0)).with_shed(0);
        assert_eq!(zero.render(), base.render());
        assert_eq!(zero.shed_rate(), 0.0);
        let mut reg_a = MetricsRegistry::new();
        let mut reg_b = MetricsRegistry::new();
        base.export_metrics(&mut reg_a);
        zero.export_metrics(&mut reg_b);
        assert_eq!(reg_a.snapshot().to_string(), reg_b.snapshot().to_string());

        let shed = SloReport::from_sim(&result(), Some(15.0)).with_shed(1);
        // 3 completed + 1 shed offered.
        assert!((shed.shed_rate() - 0.25).abs() < 1e-12);
        assert_eq!(shed.counters.get("shed"), 1);
        let text = shed.render();
        assert!(text.contains("requests shed"), "{text}");
        assert!(text.contains("(25.0%)"), "{text}");
        let mut reg = MetricsRegistry::new();
        shed.export_metrics(&mut reg);
        assert_eq!(reg.gauge("serving.shed_rate"), Some(0.25));
        assert_eq!(reg.counter("serving.shed"), Some(1));
    }

    #[test]
    fn empty_run_reports_zeroes() {
        let empty = SimResult { events: Vec::new(), completed: Vec::new(),
                                num_cores: 4, events_processed: 0 };
        let rep = SloReport::from_sim(&empty, Some(10.0));
        assert_eq!(rep.e2e.count(), 0);
        assert_eq!(rep.throughput_rps, 0.0);
        assert_eq!(rep.goodput_rps, 0.0);
        assert_eq!(rep.slo_attainment(), 1.0);
        assert!(rep.render().contains("requests completed"));
    }
}
