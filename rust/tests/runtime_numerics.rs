//! Integration: the PJRT runtime against real AOT artifacts.
//!
//! Requires `make artifacts` (skips gracefully when absent so `cargo test`
//! works on a fresh checkout). These tests are the proof that the three
//! layers compose: Pallas kernel -> JAX block -> HLO text -> Rust PJRT
//! execution, with fusion numerically equivalent to layer-wise execution.

use dlfusion::coordinator::{driver, equivalence, plan, Engine};
use dlfusion::accel::Target;
use dlfusion::optimizer::{self, Schedule};
use dlfusion::runtime::{artifact_dir, Runtime, Tensor};
use dlfusion::zoo;

fn runtime_or_skip() -> Option<Runtime> {
    if !artifact_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open_default().expect("runtime opens"))
}

#[test]
fn compiles_and_executes_every_artifact() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let names: Vec<String> = rt.manifest().artifacts.iter().map(|a| a.name.clone()).collect();
    for name in names {
        let inputs = rt.random_inputs(&name, 1).unwrap();
        let out = rt.execute(&name, &inputs).unwrap_or_else(|e| panic!("{name}: {e}"));
        let spec = rt.manifest().get(&name).unwrap();
        assert_eq!(out.shape, spec.output_shape, "{name}");
        assert!(out.data.iter().all(|v| v.is_finite()), "{name}: non-finite output");
    }
}

#[test]
fn executable_cache_hits() {
    let Some(mut rt) = runtime_or_skip() else { return };
    assert_eq!(rt.cached(), 0);
    rt.prepare("b1_c8_h16").unwrap();
    rt.prepare("b1_c8_h16").unwrap();
    assert_eq!(rt.cached(), 1);
}

#[test]
fn relu_artifacts_clamp_negative() {
    // relu_last=true artifacts must emit no negative values.
    let Some(mut rt) = runtime_or_skip() else { return };
    let inputs = rt.random_inputs("b1_c8_h16", 3).unwrap();
    let out = rt.execute("b1_c8_h16", &inputs).unwrap();
    assert!(out.data.iter().all(|&v| v >= 0.0));
    // And at least some activations actually fire.
    assert!(out.data.iter().any(|&v| v > 0.0));
}

#[test]
fn fused_equals_unfused_on_every_pair() {
    // DLFusion's central claim, on the real execution path.
    let Some(mut rt) = runtime_or_skip() else { return };
    for seed in [7u64, 99] {
        let rep = equivalence::check_fused_vs_unfused(&mut rt, seed).unwrap();
        assert!(!rep.checks.is_empty());
        for c in &rep.checks {
            assert!(c.passed, "{} diff {} (seed {seed})", c.artifact, c.max_abs_diff);
        }
    }
}

#[test]
fn golden_vectors_replay() {
    // Replays the exact inputs/outputs python recorded at AOT time: pins
    // Rust-side tensor layout, literal conversion, and the HLO round-trip.
    let Some(mut rt) = runtime_or_skip() else { return };
    let rep = equivalence::check_golden(&mut rt, 1e-4).unwrap();
    assert!(!rep.checks.is_empty(), "manifest should carry golden vectors");
    for c in &rep.checks {
        assert!(c.passed, "{} diff {}", c.artifact, c.max_abs_diff);
    }
}

#[test]
fn deterministic_execution() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let inputs = rt.random_inputs("b2_c8_h16", 5).unwrap();
    let a = rt.execute("b2_c8_h16", &inputs).unwrap();
    let b = rt.execute("b2_c8_h16", &inputs).unwrap();
    assert_eq!(a, b);
}

#[test]
fn zero_input_yields_bias_pattern() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut inputs = rt.random_inputs("b1_c8_h16", 5).unwrap();
    inputs[0] = Tensor::zeros(inputs[0].shape.clone());
    let out = rt.execute("b1_c8_h16", &inputs).unwrap();
    // x = 0 -> interior outputs are relu(bias): constant per channel in the
    // interior. Check two interior pixels match.
    let (h, w, c) = (16usize, 16usize, 8usize);
    let at = |y: usize, x: usize, ch: usize| out.data[(y * w + x) * c + ch];
    for ch in 0..c {
        assert!((at(7, 7, ch) - at(8, 8, ch)).abs() < 1e-6);
    }
}

#[test]
fn shape_mismatch_rejected() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut inputs = rt.random_inputs("b1_c8_h16", 5).unwrap();
    inputs[0] = Tensor::zeros(vec![1, 8, 8, 8]);
    assert!(rt.execute("b1_c8_h16", &inputs).is_err());
}

#[test]
fn unknown_artifact_rejected() {
    let Some(mut rt) = runtime_or_skip() else { return };
    assert!(rt.execute("nope", &[]).is_err());
}

#[test]
fn engine_construction_rejects_malformed_plans() {
    use dlfusion::coordinator::ExecutionPlan;
    use dlfusion::runtime::RuntimeError;

    let model = zoo::mini_cnn();

    // An empty plan is a RuntimeError at construction, not a panic.
    let Some(rt) = runtime_or_skip() else { return };
    let empty = ExecutionPlan { model_name: model.name.clone(), steps: Vec::new() };
    match Engine::new(rt, &model, empty, 7) {
        Err(RuntimeError::InvalidPlan(msg)) => {
            assert!(msg.contains("no steps"), "{msg}")
        }
        other => panic!("expected InvalidPlan, got {:?}",
                        other.err().map(|e| e.to_string())),
    }

    // A step pointing at a non-conv layer index has no weights: also a
    // clean construction error.
    let Some(rt) = runtime_or_skip() else { return };
    let sched = Schedule::single_block(model.num_layers(), 4);
    let mut bad = plan::build_plan(&model, &sched, rt.manifest()).unwrap();
    bad.steps[0].conv_indices.push(model.num_layers() + 100);
    match Engine::new(rt, &model, bad, 7) {
        Err(RuntimeError::InvalidPlan(msg)) => {
            assert!(msg.contains("references conv layer"), "{msg}")
        }
        other => panic!("expected InvalidPlan, got {:?}",
                        other.err().map(|e| e.to_string())),
    }

    // A step naming an artifact the manifest does not carry.
    let Some(rt) = runtime_or_skip() else { return };
    let mut unknown = plan::build_plan(&model, &sched, rt.manifest()).unwrap();
    unknown.steps[0].artifact = "no_such_artifact".to_string();
    assert!(matches!(Engine::new(rt, &model, unknown, 7),
                     Err(RuntimeError::UnknownArtifact(_))));
}

#[test]
fn engine_infer_matches_unfused_and_serves() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = zoo::mini_cnn();
    let sim = dlfusion::accel::Simulator::new(Target::mlu100());
    let sched = optimizer::dlfusion_schedule(&model, &sim.spec);
    let ex_plan = plan::build_plan(&model, &sched, rt.manifest()).unwrap();
    assert_eq!(ex_plan.num_convs(), 6);
    let mut engine = Engine::new(rt, &model, ex_plan, 99).unwrap();

    let x = engine.random_input(5);
    let fused = engine.infer(x.clone()).unwrap();
    let unfused = engine.infer_unfused(x).unwrap();
    assert!(fused.max_abs_diff(&unfused) <= equivalence::FUSION_TOL,
            "diff {}", fused.max_abs_diff(&unfused));

    let cfg = driver::DriverConfig { requests: 8, warmup: 1, seed: 3, verify_each: true };
    let rep = driver::serve(&mut engine, &cfg).unwrap();
    assert_eq!(rep.counters.get("requests"), 8);
    assert_eq!(rep.counters.get("equivalence_failures"), 0);
    assert_eq!(rep.latency.count(), 8);
    assert!(rep.fps() > 0.0);
}

#[test]
fn layerwise_schedule_also_plans_and_runs() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = zoo::mini_cnn();
    let sched = Schedule::layerwise(model.num_layers(), 1);
    let ex_plan = plan::build_plan(&model, &sched, rt.manifest()).unwrap();
    assert_eq!(ex_plan.num_fused_steps(), 0);
    let mut engine = Engine::new(rt, &model, ex_plan, 42).unwrap();
    let y = engine.infer(engine.random_input(1)).unwrap();
    assert_eq!(y.shape, vec![1, 16, 16, 8]);
}
