//! Integration: the multi-tenant serving simulator — seed determinism, the
//! load-aware allocator's divergence from the single-request optimum, and
//! end-to-end SLO accounting (ISSUE acceptance criteria).

use dlfusion::accel::{Simulator, Target};
use dlfusion::serving::{self, AllocationRequest, ArrivalProcess,
                        ClusterConfig, DispatchPolicy, ModelMix, SimEventKind,
                        SimulationRun, SloReport};
use dlfusion::zoo;

/// Same seed ⇒ identical event trace and rendered SLO report; a different
/// seed diverges. No wall clock enters simulated results.
#[test]
fn same_seed_pins_the_event_trace_and_report() {
    let sim = Simulator::new(Target::mlu100());
    let mix = ModelMix::uniform(vec![zoo::alexnet(), zoo::mini_cnn()]);
    let plan =
        AllocationRequest::new(&sim, &mix).slo_ms(Some(50.0)).plan().unwrap();
    let run = |seed: u64| {
        let trace = serving::generate_trace(
            &mix, ArrivalProcess::OpenPoisson { rate_rps: 400.0 }, 120, seed);
        let cfg = ClusterConfig { num_cores: sim.spec.num_cores,
                                  policy: DispatchPolicy::Fifo };
        let result = SimulationRun::new(&cfg, &plan.services(true))
            .trace(&trace)
            .run()
            .unwrap();
        let report = SloReport::from_sim(&result, Some(50.0)).render();
        (result, report)
    };
    let (r1, rep1) = run(42);
    let (r2, rep2) = run(42);
    assert_eq!(r1.events, r2.events);
    assert_eq!(r1.completed, r2.completed);
    assert_eq!(rep1, rep2);
    let (r3, _) = run(43);
    assert_ne!(r1.events, r3.events, "different seed must change the trace");
}

/// The ISSUE's headline acceptance criterion: on a pinned multi-model
/// scenario the load-aware allocator picks a different MP than the
/// single-request optimum and achieves strictly higher simulated aggregate
/// throughput under saturating load.
#[test]
fn load_aware_mp_diverges_and_wins_aggregate_throughput() {
    let sim = Simulator::new(Target::mlu100());
    let mix = ModelMix::uniform(vec![zoo::vgg19(), zoo::resnet18()]);
    let plan = AllocationRequest::new(&sim, &mix).plan().unwrap();

    assert!(plan.models.iter().any(|m| m.diverged()),
            "expected at least one model's load-aware MP to differ from its \
             single-request optimum: {:?}",
            plan.models
                .iter()
                .map(|m| (m.name.clone(), m.single.cores, m.load_aware.cores))
                .collect::<Vec<_>>());
    for m in &plan.models {
        // Load-aware never reserves more cores than the latency optimum
        // needs, and never spends more core-ms per request.
        assert!(m.load_aware.cores <= m.single.cores, "{}", m.name);
        assert!(m.load_aware.core_ms() <= m.single.core_ms() + 1e-12, "{}", m.name);
        // But it is slower per request — that's the trade.
        assert!(m.load_aware.service_ms >= m.single.service_ms, "{}", m.name);
    }

    // Saturating closed-loop scenario: the identical pinned trace under
    // both allocations.
    let trace = serving::generate_trace(
        &mix, ArrivalProcess::ClosedLoop { concurrency: 64 }, 200, 7);
    let cfg = ClusterConfig { num_cores: sim.spec.num_cores,
                              policy: DispatchPolicy::Fifo };
    let single = SimulationRun::new(&cfg, &plan.services(false))
        .trace(&trace)
        .closed_loop(Some(64))
        .run()
        .unwrap();
    let load = SimulationRun::new(&cfg, &plan.services(true))
        .trace(&trace)
        .closed_loop(Some(64))
        .run()
        .unwrap();
    assert_eq!(single.completed.len(), 200);
    assert_eq!(load.completed.len(), 200);
    assert!(load.throughput_rps() > single.throughput_rps(),
            "load-aware {} req/s must strictly beat single-request {} req/s",
            load.throughput_rps(), single.throughput_rps());
    // The predicted capacity ordering agrees with the simulation.
    assert!(plan.predicted_capacity_rps(sim.spec.num_cores, true)
            > plan.predicted_capacity_rps(sim.spec.num_cores, false));
}

/// Every request arrives, starts, and finishes exactly once, in a causally
/// consistent order, under both dispatch policies and a bursty trace.
#[test]
fn event_trace_is_causally_consistent_under_both_policies() {
    let sim = Simulator::new(Target::mlu100());
    let mix = ModelMix::uniform(vec![zoo::alexnet(), zoo::mini_cnn()]);
    let plan = AllocationRequest::new(&sim, &mix).plan().unwrap();
    let trace = serving::generate_trace(
        &mix, ArrivalProcess::Bursty { rate_rps: 600.0, burst: 8 }, 96, 13);
    for policy in [DispatchPolicy::Fifo, DispatchPolicy::ShortestJobFirst] {
        let cfg = ClusterConfig { num_cores: sim.spec.num_cores, policy };
        let result = SimulationRun::new(&cfg, &plan.services(true))
            .trace(&trace)
            .run()
            .unwrap();
        assert_eq!(result.completed.len(), 96, "{}", policy.name());
        for w in result.events.windows(2) {
            assert!(w[1].time_ms >= w[0].time_ms);
        }
        let count = |want: fn(&SimEventKind) -> bool| {
            result.events.iter().filter(|e| want(&e.kind)).count()
        };
        assert_eq!(count(|k| matches!(k, SimEventKind::Arrive { .. })), 96);
        assert_eq!(count(|k| matches!(k, SimEventKind::Start { .. })), 96);
        assert_eq!(count(|k| matches!(k, SimEventKind::Finish { .. })), 96);
        for c in &result.completed {
            assert!(c.arrival_ms <= c.start_ms && c.start_ms < c.finish_ms);
        }
        assert!(result.utilization() > 0.0 && result.utilization() <= 1.0);
    }
}

/// SJF reduces mean end-to-end latency relative to FIFO on a mix with very
/// different service times under backlog (the classic scheduling result),
/// while serving the same request set.
#[test]
fn sjf_improves_mean_latency_on_a_skewed_mix() {
    let sim = Simulator::new(Target::mlu100());
    let mix = ModelMix::uniform(vec![zoo::vgg19(), zoo::mini_cnn()]);
    let plan = AllocationRequest::new(&sim, &mix).plan().unwrap();
    // Pin every request to one core: with equal widths the comparison is
    // pure scheduling (no packing effects), where shortest-first is the
    // classical mean-latency winner.
    let mut services = plan.services(true);
    for s in &mut services {
        s.cores = 1;
    }
    let trace = serving::generate_trace(
        &mix, ArrivalProcess::ClosedLoop { concurrency: 48 }, 150, 3);
    let run = |policy| {
        let cfg = ClusterConfig { num_cores: sim.spec.num_cores, policy };
        let r = SimulationRun::new(&cfg, &services)
            .trace(&trace)
            .closed_loop(Some(48))
            .run()
            .unwrap();
        SloReport::from_sim(&r, None)
    };
    let fifo = run(DispatchPolicy::Fifo);
    let sjf = run(DispatchPolicy::ShortestJobFirst);
    assert_eq!(fifo.counters.get("requests"), sjf.counters.get("requests"));
    let mean = |rep: &SloReport| rep.e2e.summary().unwrap().mean;
    assert!(mean(&sjf) <= mean(&fifo),
            "sjf mean {} vs fifo mean {}", mean(&sjf), mean(&fifo));
}

/// Same seed ⇒ identical event trace, completion records (including batch
/// sizes), and rendered report under the dynamic-batching policy; a
/// different seed diverges. The batch former introduces no hidden
/// nondeterminism (PR 4 acceptance).
#[test]
fn same_seed_pins_the_batched_serving_trace() {
    let sim = Simulator::new(Target::mlu100());
    let mix = ModelMix::uniform(vec![zoo::vgg19(), zoo::resnet18()]);
    let max_batch = serving::DEFAULT_MAX_BATCH;
    let plan = AllocationRequest::new(&sim, &mix)
        .max_batch(max_batch)
        .plan()
        .unwrap();
    let services = plan.services(true);
    let rate = 2.0 * plan.predicted_capacity_rps(sim.spec.num_cores, true);
    let run = |seed: u64| {
        let trace = serving::generate_trace(
            &mix, ArrivalProcess::OpenPoisson { rate_rps: rate }, 200, seed);
        let cfg = ClusterConfig {
            num_cores: sim.spec.num_cores,
            policy: DispatchPolicy::Batch { max_batch, max_wait_ms: 2.0 },
        };
        let result = SimulationRun::new(&cfg, &services)
            .trace(&trace)
            .run()
            .unwrap();
        let report = SloReport::from_sim(&result, Some(100.0)).render();
        (result, report)
    };
    let (r1, rep1) = run(42);
    let (r2, rep2) = run(42);
    assert_eq!(r1, r2);
    assert_eq!(rep1, rep2);
    let (r3, _) = run(43);
    assert_ne!(r1.events, r3.events, "different seed must change the trace");
    // Under 2x-capacity overload the former actually forms batches.
    assert!(r1.completed.iter().any(|c| c.batch > 1),
            "no batched invocations formed");
    assert!(r1.completed.iter().all(|c| c.batch <= max_batch));
}

/// The PR 4 headline acceptance criterion: on the vgg19+resnet18 Poisson
/// mix, dynamic batching achieves strictly higher simulated goodput than
/// one-request-at-a-time FIFO at the same SLO. Batching amortizes the
/// per-invocation weight movement, pipeline fill, and launch/sync
/// overheads, so its sustainable capacity is strictly higher; under
/// overload at the same offered rate that capacity edge compounds into
/// both more SLO-met completions and a shorter makespan.
#[test]
fn dynamic_batching_beats_fifo_goodput_on_the_poisson_mix() {
    let sim = Simulator::new(Target::mlu100());
    let mix = ModelMix::uniform(vec![zoo::vgg19(), zoo::resnet18()]);
    let max_batch = serving::DEFAULT_MAX_BATCH;
    let plan = AllocationRequest::new(&sim, &mix)
        .max_batch(max_batch)
        .plan()
        .unwrap();
    let services = plan.services(true);
    // The batched capacity edge exists in the plan itself.
    let cap1 = plan.predicted_capacity_rps(sim.spec.num_cores, true);
    let cap_b = plan.predicted_batched_capacity_rps(sim.spec.num_cores);
    assert!(cap_b > cap1, "batched capacity {cap_b} vs batch-1 {cap1}");
    // Overload both policies at 2.5x the batch-1 capacity, with an SLO
    // generous to either policy's invocation latency (so the comparison is
    // about sustained goodput, not about the SLO clipping one invocation).
    let rate = 2.5 * cap1;
    let slo = 3.0 * services
        .iter()
        .map(|s| s.service_at(max_batch))
        .fold(0.0, f64::max);
    let trace = serving::generate_trace(
        &mix, ArrivalProcess::OpenPoisson { rate_rps: rate }, 600, 11);
    let run = |policy| {
        let cfg = ClusterConfig { num_cores: sim.spec.num_cores, policy };
        let result = SimulationRun::new(&cfg, &services)
            .trace(&trace)
            .run()
            .unwrap();
        SloReport::from_sim(&result, Some(slo))
    };
    let fifo = run(DispatchPolicy::Fifo);
    let batch = run(DispatchPolicy::Batch { max_batch, max_wait_ms: 2.0 });
    assert_eq!(fifo.counters.get("requests"), batch.counters.get("requests"));
    assert!(batch.goodput_rps > fifo.goodput_rps,
            "batch {} req/s goodput must strictly beat fifo {} req/s \
             (SLO {slo:.1} ms, offered {rate:.0} req/s)",
            batch.goodput_rps, fifo.goodput_rps);
    assert!(batch.throughput_rps > fifo.throughput_rps,
            "batch {} req/s vs fifo {} req/s",
            batch.throughput_rps, fifo.throughput_rps);
}

/// A binding SLO changes the operating point and the goodput accounting
/// reflects the deadline.
#[test]
fn slo_report_accounts_goodput_under_deadline() {
    let sim = Simulator::new(Target::mlu100());
    let mix = ModelMix::uniform(vec![zoo::alexnet()]);
    let plan = AllocationRequest::new(&sim, &mix).plan().unwrap();
    // Overload: arrivals at ~4x the pool's capacity at the load-aware point.
    let cap = plan.predicted_capacity_rps(sim.spec.num_cores, true);
    let trace = serving::generate_trace(
        &mix, ArrivalProcess::OpenPoisson { rate_rps: 4.0 * cap }, 300, 21);
    let cfg = ClusterConfig { num_cores: sim.spec.num_cores,
                              policy: DispatchPolicy::Fifo };
    let result = SimulationRun::new(&cfg, &plan.services(true))
        .trace(&trace)
        .run()
        .unwrap();
    let slo = plan.models[0].load_aware.service_ms * 2.0;
    let rep = SloReport::from_sim(&result, Some(slo));
    // Overloaded: queues build, some requests must miss the deadline.
    assert!(rep.counters.get("slo_violations") > 0, "{}", rep.render());
    assert!(rep.goodput_rps < rep.throughput_rps);
    assert!(rep.slo_attainment() < 1.0);
    // Queueing dominates service in the tail under overload.
    let q = rep.queueing.summary().unwrap();
    assert!(q.max > 0.0);
    // Percentiles are ordered.
    let ps = rep.e2e.percentiles(&[50.0, 95.0, 99.0]).unwrap();
    assert!(ps[0] <= ps[1] && ps[1] <= ps[2]);
}
