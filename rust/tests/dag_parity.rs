//! Integration: the DAG IR vs the range-based path (rust/docs/DESIGN.md §13).
//!
//! Two contracts are pinned here:
//!
//! 1. **Linear-chain parity.** Importing any legacy chain as a DAG
//!    (`DagModel::from_model` → `linearize`) yields `cuts: None` and a model
//!    whose tuning outcome is *bit-identical* — same schedule, same
//!    `predicted_ms` bits — to the range-based path, for every backend.
//!    An explicit all-legal cut set is likewise the identity constraint.
//!
//! 2. **Branching constraint.** A genuinely branching DAG (the zoo's
//!    `resnet18-dag`) tunes end-to-end with fusion confined to its legal cut
//!    set, and the constrained oracle partition differs from both the
//!    unconstrained oracle on the same linearization and the legacy
//!    faked-sequential chain.

use std::collections::BTreeSet;

use dlfusion::accel::{Simulator, Target};
use dlfusion::graph::dag::{self, load_dlm, to_dlm_v2, DagModel, LoadedModel};
use dlfusion::graph::{format as dlm, Model};
use dlfusion::optimizer::Strategy;
use dlfusion::tuner::{backend_by_name, Algorithm1, Annealer, Exhaustive, OracleDp,
                      TableStrategy, Tuner, TuningError, TuningOutcome,
                      TuningRequest};
use dlfusion::zoo;

fn sim() -> Simulator {
    Simulator::new(Target::mlu100())
}

/// Run one fresh backend instance against a model, optionally constrained.
fn tune(s: &Simulator, m: &Model, backend: &str, cuts: Option<Vec<usize>>)
        -> Result<TuningOutcome, TuningError> {
    let mut t = backend_by_name(backend).expect("known backend");
    let mut req = TuningRequest::new(s, m);
    if let Some(c) = cuts {
        req = req.allowed_cuts(c);
    }
    req.run(t.as_mut())
}

fn assert_bit_identical(a: &TuningOutcome, b: &TuningOutcome, label: &str) {
    assert_eq!(a.schedule, b.schedule, "{label}: schedules diverge");
    assert_eq!(a.predicted_ms.to_bits(), b.predicted_ms.to_bits(),
               "{label}: predicted_ms bits diverge");
    assert_eq!(a.batch, b.batch, "{label}: batch diverges");
}

#[test]
fn linear_dag_lowering_reproduces_the_legacy_model_layer_for_layer() {
    for m in zoo::all_models() {
        let d = DagModel::from_model(&m);
        let lin = dag::linearize(&d).unwrap_or_else(|e| panic!("{}: {e}", m.name));
        assert!(lin.cuts.is_none(), "{}: chain import must be unconstrained", m.name);
        assert_eq!(lin.model.name, m.name);
        assert_eq!(lin.model.input, m.input, "{}", m.name);
        assert_eq!(lin.model.layers, m.layers, "{}", m.name);
    }
}

#[test]
fn dlm_roundtrip_is_a_fixed_point_for_every_zoo_model() {
    // v1: text → model → text is stable, for every chain.
    for m in zoo::all_models() {
        let text = dlm::to_dlm(&m);
        let re = dlm::from_dlm(&text).unwrap_or_else(|e| panic!("{}: {e}", m.name));
        assert_eq!(re, m, "{}: v1 parse must reproduce the model", m.name);
        assert_eq!(dlm::to_dlm(&re), text, "{}: v1 serialization unstable", m.name);
        // The version dispatcher agrees with the direct v1 parser.
        match load_dlm(&text).unwrap() {
            LoadedModel::Linear(via) => assert_eq!(via, m, "{}", m.name),
            LoadedModel::Dag(_) => panic!("{}: v1 text loaded as a dag", m.name),
        }
    }
    // v2: every chain imported as a DAG, and every native DAG, round-trips.
    let imported = zoo::all_models().iter().map(DagModel::from_model).collect::<Vec<_>>();
    for d in imported.into_iter().chain(zoo::dag_models()) {
        let text = to_dlm_v2(&d);
        match load_dlm(&text).unwrap_or_else(|e| panic!("{}: {e}", d.name)) {
            LoadedModel::Dag(re) => {
                assert_eq!(re, d, "{}: v2 parse must reproduce the dag", d.name);
                assert_eq!(to_dlm_v2(&re), text, "{}: v2 serialization unstable", d.name);
            }
            LoadedModel::Linear(_) => panic!("{}: v2 text loaded as v1", d.name),
        }
    }
}

#[test]
fn linear_dag_import_is_bit_identical_for_algorithm1_on_every_zoo_model() {
    let s = sim();
    for m in zoo::all_models() {
        let lin = dag::linearize(&DagModel::from_model(&m)).unwrap();
        assert!(lin.cuts.is_none(), "{}", m.name);
        let base = tune(&s, &m, "algorithm1", None).unwrap();
        let via = tune(&s, &lin.model, "algorithm1", None).unwrap();
        assert_bit_identical(&base, &via, &format!("{} algorithm1", m.name));
    }
}

#[test]
fn linear_dag_import_is_bit_identical_for_search_backends() {
    let s = sim();
    for m in [zoo::alexnet(), zoo::resnet18()] {
        let lin = dag::linearize(&DagModel::from_model(&m)).unwrap();
        for backend in ["oracle", "anneal"] {
            let base = tune(&s, &m, backend, None).unwrap();
            let via = tune(&s, &lin.model, backend, None).unwrap();
            assert_bit_identical(&base, &via, &format!("{} {backend}", m.name));
        }
    }
    // Exhaustive and the Table III strategies certify on the tiny chain.
    let m = zoo::mini_cnn();
    let lin = dag::linearize(&DagModel::from_model(&m)).unwrap();
    let base = tune(&s, &m, "exhaustive", None).unwrap();
    let via = tune(&s, &lin.model, "exhaustive", None).unwrap();
    assert_bit_identical(&base, &via, "mini_cnn exhaustive");
    for st in Strategy::ALL {
        let base = TuningRequest::new(&s, &m).run(&mut TableStrategy(st)).unwrap();
        let via = TuningRequest::new(&s, &lin.model)
            .run(&mut TableStrategy(st))
            .unwrap();
        assert_bit_identical(&base, &via, &format!("mini_cnn {st}"));
    }
}

#[test]
fn an_explicit_all_legal_cut_set_is_the_identity_constraint() {
    let s = sim();
    let m = zoo::alexnet();
    let all: Vec<usize> = (0..=m.num_layers()).collect();
    for backend in ["algorithm1", "oracle", "anneal"] {
        let free = tune(&s, &m, backend, None).unwrap();
        let masked = tune(&s, &m, backend, Some(all.clone())).unwrap();
        assert_bit_identical(&free, &masked, &format!("alexnet {backend}"));
    }
    let m = zoo::mini_cnn();
    let all: Vec<usize> = (0..=m.num_layers()).collect();
    let free = tune(&s, &m, "exhaustive", None).unwrap();
    let masked = tune(&s, &m, "exhaustive", Some(all)).unwrap();
    assert_bit_identical(&free, &masked, "mini_cnn exhaustive");
}

/// The pinned branching result: on the true ResNet-18 DAG the oracle's
/// fusion partition is *not* what either the unconstrained DP on the same
/// linearization or the legacy faked-sequential chain produces — the skip
/// edges genuinely reshape the fusion space.
#[test]
fn branching_resnet18_oracle_partition_differs_from_the_sequential_fake() {
    let s = sim();
    let d = zoo::resnet18_dag();
    let lin = dag::linearize(&d).unwrap();
    let cuts = lin.cuts.clone().expect("resnet18-dag must really branch");
    let legal: BTreeSet<usize> = cuts.iter().copied().collect();

    let constrained = tune(&s, &lin.model, "oracle", Some(cuts)).unwrap();
    for b in &constrained.schedule.blocks {
        assert!(legal.contains(&b.start) && legal.contains(&b.end),
                "oracle block {}..{} crosses a live skip edge", b.start, b.end);
    }

    // The constraint binds: unconstrained DP on the same linearization cuts
    // where a skip connection is still live (interior legal positions are
    // almost never the multiples of four the free reduced DP is limited to).
    let free = tune(&s, &lin.model, "oracle", None).unwrap();
    let free_crosses_skip = free.schedule.blocks.iter().any(
        |b| !legal.contains(&b.start) || !legal.contains(&b.end));
    if free_crosses_skip {
        assert_ne!(free.schedule, constrained.schedule,
                   "the legal-cut constraint never bound");
    }

    // And the faked-sequential chain's oracle partition is different again.
    let legacy = tune(&s, &zoo::resnet18(), "oracle", None).unwrap();
    assert_ne!(legacy.schedule.blocks, constrained.schedule.blocks,
               "dag-constrained partition matches the sequential fake");
}

#[test]
fn branching_resnet18_tunes_through_every_constraint_aware_backend() {
    let s = sim();
    let lin = dag::linearize(&zoo::resnet18_dag()).unwrap();
    let cuts = lin.cuts.clone().unwrap();
    let legal: BTreeSet<usize> = cuts.iter().copied().collect();
    for backend in ["algorithm1", "oracle", "anneal"] {
        let out = tune(&s, &lin.model, backend, Some(cuts.clone())).unwrap();
        assert!(out.predicted_ms.is_finite() && out.predicted_ms > 0.0,
                "{backend}");
        for b in &out.schedule.blocks {
            assert!(legal.contains(&b.start) && legal.contains(&b.end),
                    "{backend}: block {}..{} crosses a live skip edge",
                    b.start, b.end);
        }
    }
}

#[test]
fn table_strategies_reject_cut_constrained_requests() {
    let s = sim();
    let lin = dag::linearize(&zoo::resnet18_dag()).unwrap();
    let req = TuningRequest::new(&s, &lin.model).allowed_cuts(lin.cuts.unwrap());
    let err = req.run(&mut TableStrategy(Strategy::ALL[0])).unwrap_err();
    assert!(matches!(err, TuningError::InvalidRequest(_)), "{err:?}");
}

#[test]
fn out_of_range_cut_positions_are_a_structured_error() {
    let s = sim();
    let m = zoo::mini_cnn();
    let bad = vec![0, 3, m.num_layers() + 1];
    for backend in [
        Box::new(Algorithm1) as Box<dyn Tuner>,
        Box::new(OracleDp::reduced()),
        Box::new(Annealer::new()),
        Box::new(Exhaustive),
    ] {
        let mut backend = backend;
        let err = TuningRequest::new(&s, &m)
            .allowed_cuts(bad.clone())
            .run(backend.as_mut())
            .unwrap_err();
        assert!(matches!(err, TuningError::InvalidRequest(_)), "{err:?}");
    }
}
