//! Integration: the unified tuner API (rust/docs/DESIGN.md §8).
//!
//! Every `Tuner` backend is pinned bit-identical to the legacy free
//! function it replaces — same schedule, same predicted latency — and the
//! shared-context path is shown to reuse the memoized cache across
//! backends. The deprecated shims are exercised deliberately: they are the
//! replay references.
#![allow(deprecated)]

use dlfusion::accel::{Simulator, Target};
use dlfusion::optimizer::{self, Strategy};
use dlfusion::search::{self, AnnealConfig};
use dlfusion::tuner::{Algorithm1, Annealer, Exhaustive, OracleDp, TableStrategy,
                      Tuner, TuningError, TuningRequest};
use dlfusion::zoo;

fn sim() -> Simulator {
    Simulator::new(Target::mlu100())
}

/// A conv-only model small enough for exhaustive enumeration.
fn tiny_model(n: usize) -> dlfusion::graph::Model {
    let m = zoo::identical_conv_model(
        "tiny", dlfusion::graph::ConvSpec::same(64, 64, 28, 3), n);
    dlfusion::graph::Model::new(
        "tiny",
        m.input,
        m.layers.into_iter().filter(|l| l.is_compute()).collect(),
    )
}

#[test]
fn algorithm1_matches_legacy_dlfusion_schedule() {
    let s = sim();
    for m in [zoo::resnet18(), zoo::alexnet(), zoo::vgg19()] {
        let out = TuningRequest::new(&s, &m).run(&mut Algorithm1).unwrap();
        let legacy = optimizer::dlfusion_schedule(&m, &s.spec);
        assert_eq!(out.schedule, legacy, "{}", m.name);
        assert_eq!(out.predicted_ms, s.run_schedule(&m, &legacy).total_ms,
                   "{}", m.name);
        assert_eq!(out.tuner, "algorithm1");
    }
}

#[test]
fn table_strategies_match_legacy_run_strategy() {
    let s = sim();
    for m in [zoo::alexnet(), zoo::resnet18()] {
        for st in Strategy::ALL {
            let out = TuningRequest::new(&s, &m)
                .run(&mut TableStrategy(st))
                .unwrap();
            let (sched, rep) = optimizer::run_strategy(&s, &m, st);
            assert_eq!(out.schedule, sched, "{} {st}", m.name);
            assert_eq!(out.predicted_ms, rep.total_ms, "{} {st}", m.name);
        }
    }
}

#[test]
fn oracle_dp_matches_legacy_oracle_schedule() {
    let s = sim();
    for m in [zoo::alexnet(), zoo::resnet18()] {
        let out = TuningRequest::new(&s, &m).run(&mut OracleDp::reduced()).unwrap();
        let (sched, st) = search::oracle_schedule(&s, &m);
        assert_eq!(out.schedule, sched, "{}", m.name);
        assert_eq!(out.predicted_ms, s.run_schedule(&m, &sched).total_ms,
                   "{}", m.name);
        // The unified stats carry the DP's SearchStats counters verbatim.
        assert_eq!(out.stats.evaluations, st.evaluations as u64);
        assert_eq!(out.stats.blocks_considered, st.blocks_considered as u64);
        assert_eq!(out.stats.cache_hits + out.stats.cache_misses,
                   out.stats.evaluations);
    }
}

#[test]
fn oracle_dp_full_matches_legacy_full_oracle() {
    let s = sim();
    let m = zoo::alexnet();
    let out = TuningRequest::new(&s, &m).run(&mut OracleDp::full()).unwrap();
    let (sched, _) = search::oracle_schedule_full(&s, &m);
    assert_eq!(out.schedule, sched);
}

#[test]
fn annealer_matches_legacy_anneal_under_fixed_seed() {
    let s = sim();
    let cfg = AnnealConfig { iterations: 300, ..Default::default() };
    for m in [zoo::alexnet(), zoo::resnet18()] {
        let out = TuningRequest::new(&s, &m)
            .anneal_config(cfg)
            .run(&mut Annealer::new())
            .unwrap();
        let (sched, cost) = search::anneal(&s, &m, &cfg, None);
        assert_eq!(out.schedule, sched, "{}", m.name);
        assert_eq!(out.predicted_ms, cost, "{}", m.name);
        assert!(!out.stats.truncated);
    }
}

#[test]
fn warm_started_annealer_matches_legacy_warm_start() {
    let s = sim();
    let m = zoo::resnet18();
    let cfg = AnnealConfig { iterations: 200, ..Default::default() };
    let dlf = optimizer::dlfusion_schedule(&m, &s.spec);
    let out = TuningRequest::new(&s, &m)
        .anneal_config(cfg)
        .run(&mut Annealer::from_schedule(dlf.clone()))
        .unwrap();
    let (sched, cost) = search::anneal(&s, &m, &cfg, Some(dlf));
    assert_eq!(out.schedule, sched);
    assert_eq!(out.predicted_ms, cost);
}

#[test]
fn exhaustive_matches_legacy_enumeration() {
    let s = sim();
    let mp_set = vec![1, 2, 4, 8];
    for n in [3usize, 6] {
        let m = tiny_model(n);
        let out = TuningRequest::new(&s, &m)
            .mp_candidates(mp_set.clone())
            .run(&mut Exhaustive)
            .unwrap();
        let (sched, visited) = search::exhaustive_schedule(&s, &m, &mp_set);
        assert_eq!(out.schedule, sched, "n={n}");
        assert_eq!(out.stats.space_visited, visited, "n={n}");
        assert_eq!(out.predicted_ms, s.run_schedule(&m, &sched).total_ms,
                   "n={n}");
    }
}

#[test]
fn constrained_oracle_honours_request_mps() {
    let s = sim();
    let m = zoo::resnet18();
    let out = TuningRequest::new(&s, &m)
        .mp_candidates(vec![1, 4])
        .run(&mut OracleDp::constrained())
        .unwrap();
    assert!(out.schedule.blocks.iter().all(|b| b.mp == 1 || b.mp == 4),
            "{}", out.schedule.summary());
}

#[test]
fn compare_shares_one_engine_across_tuners() {
    let s = sim();
    let m = zoo::alexnet();
    let request = TuningRequest::new(&s, &m);
    let mut tuners: Vec<Box<dyn Tuner>> = vec![
        Box::new(TableStrategy(Strategy::BruteForce)),
        Box::new(OracleDp::reduced()),
        Box::new(Algorithm1),
    ];
    let cmp = request.compare(&mut tuners).unwrap();
    assert_eq!(cmp.outcomes.len(), 3);
    // Strategy 7 *is* the reduced oracle: the second run replays the same
    // DP over a warm cache and computes nothing new.
    assert_eq!(cmp.outcomes[0].schedule, cmp.outcomes[1].schedule);
    assert_eq!(cmp.outcomes[1].stats.cache_misses, 0);
    assert!(cmp.outcomes[1].stats.cache_hits > 0);
    // The report renders without panicking and names every tuner.
    let report = cmp.render("parity");
    for o in &cmp.outcomes {
        assert!(report.contains(&o.tuner), "{report}");
    }
    assert!(cmp.best().unwrap().predicted_ms
            <= cmp.outcomes[2].predicted_ms + 1e-12);
}

#[test]
fn budget_errors_and_truncation() {
    let s = sim();
    let m = zoo::alexnet();
    // The DP cannot return a partial result: budget exhaustion is an error.
    let err = TuningRequest::new(&s, &m)
        .max_evaluations(4)
        .run(&mut OracleDp::reduced())
        .unwrap_err();
    assert!(matches!(err, TuningError::BudgetExhausted { budget: 4, .. }), "{err}");
    // Strategy 7 is the same DP and honours the budget identically.
    let err = TuningRequest::new(&s, &m)
        .max_evaluations(4)
        .run(&mut TableStrategy(Strategy::BruteForce))
        .unwrap_err();
    assert!(matches!(err, TuningError::BudgetExhausted { budget: 4, .. }), "{err}");
    // The annealer truncates and still returns a valid best-so-far.
    let out = TuningRequest::new(&s, &m)
        .max_evaluations(m.num_layers() as u64 + 8)
        .run(&mut Annealer::new())
        .unwrap();
    assert!(out.stats.truncated);
    out.schedule.validate(m.num_layers(), s.spec.num_cores).unwrap();
    // Exhaustive refuses large models with an error, not a panic.
    let err = TuningRequest::new(&s, &m).run(&mut Exhaustive).unwrap_err();
    assert!(matches!(err, TuningError::ModelTooLarge { .. }), "{err}");
}

#[test]
fn explicit_batch_one_is_the_default_request_bit_for_bit() {
    // The acceptance pin: with batch candidates [1] — explicit or default —
    // every backend returns exactly its pre-batch result (schedule and
    // predicted latency), for strategies 1-7, the oracle DP, and the
    // seeded annealer.
    let s = sim();
    let m = zoo::alexnet();
    let cfg = AnnealConfig { iterations: 200, ..Default::default() };
    let mut backends: Vec<Box<dyn Tuner>> = vec![
        Box::new(Algorithm1),
        Box::new(OracleDp::reduced()),
        Box::new(Annealer::new()),
    ];
    for st in Strategy::ALL {
        backends.push(Box::new(TableStrategy(st)));
    }
    for backend in &mut backends {
        let default_out = TuningRequest::new(&s, &m)
            .anneal_config(cfg)
            .run(backend.as_mut())
            .unwrap();
        let explicit_out = TuningRequest::new(&s, &m)
            .anneal_config(cfg)
            .batch_candidates(vec![1])
            .run(backend.as_mut())
            .unwrap();
        assert_eq!(default_out.batch, 1, "{}", default_out.tuner);
        assert_eq!(default_out.schedule, explicit_out.schedule, "{}", default_out.tuner);
        assert_eq!(default_out.predicted_ms, explicit_out.predicted_ms,
                   "{}", default_out.tuner);
        // And the per-sample view is the invocation view at batch 1.
        assert_eq!(default_out.per_sample_ms(), default_out.predicted_ms);
    }
}

#[test]
fn batch_candidates_co_optimize_per_sample_latency() {
    let s = sim();
    let m = zoo::vgg19();
    for backend in [&mut OracleDp::reduced() as &mut dyn Tuner,
                    &mut Algorithm1 as &mut dyn Tuner] {
        let base = TuningRequest::new(&s, &m).run(backend).unwrap();
        let joint = TuningRequest::new(&s, &m)
            .batch_candidates(vec![1, 2, 4, 8])
            .run(backend)
            .unwrap();
        // Weight amortization makes some batch > 1 strictly better per
        // sample, so the joint search must leave batch 1.
        assert!(joint.batch > 1, "{}: stayed at batch {}", joint.tuner, joint.batch);
        assert!(joint.per_sample_ms() < base.predicted_ms,
                "{}: {} per sample vs {} at batch 1",
                joint.tuner, joint.per_sample_ms(), base.predicted_ms);
        // The invocation is slower than one batch-1 inference — that's the
        // trade — and FPS accounts for the whole batch.
        assert!(joint.predicted_ms > base.predicted_ms);
        assert!(joint.fps() > base.fps());
        // Stats aggregate the whole joint search, not just the winning
        // candidate's run.
        assert!(joint.stats.evaluations > base.stats.evaluations,
                "{}: joint {} evals vs single-batch {}",
                joint.tuner, joint.stats.evaluations, base.stats.evaluations);
    }
}

#[test]
fn annealer_batch_runs_are_deterministic() {
    let s = sim();
    let m = zoo::alexnet();
    let cfg = AnnealConfig { iterations: 150, ..Default::default() };
    let run = || {
        TuningRequest::new(&s, &m)
            .anneal_config(cfg)
            .batch_candidates(vec![1, 4])
            .run(&mut Annealer::new())
            .unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.schedule, b.schedule);
    assert_eq!(a.batch, b.batch);
    assert_eq!(a.predicted_ms, b.predicted_ms);
}

#[test]
fn invalid_batch_requests_are_rejected() {
    let s = sim();
    let m = tiny_model(3);
    let err = TuningRequest::new(&s, &m)
        .batch_candidates(vec![])
        .run(&mut Algorithm1)
        .unwrap_err();
    assert_eq!(err, TuningError::EmptyBatchSet);
    let err = TuningRequest::new(&s, &m)
        .batch_candidates(vec![1, 0])
        .run(&mut OracleDp::reduced())
        .unwrap_err();
    assert!(matches!(err, TuningError::InvalidBatch { batch: 0 }), "{err}");
}

#[test]
fn invalid_mp_requests_are_rejected() {
    let s = sim();
    let m = tiny_model(3);
    let err = TuningRequest::new(&s, &m)
        .mp_candidates(vec![])
        .run(&mut OracleDp::constrained())
        .unwrap_err();
    assert_eq!(err, TuningError::EmptyMpSet);
    let err = TuningRequest::new(&s, &m)
        .mp_candidates(vec![1, 64])
        .run(&mut Exhaustive)
        .unwrap_err();
    assert!(matches!(err, TuningError::InvalidMp { mp: 64, .. }), "{err}");
}
