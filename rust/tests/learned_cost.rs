//! Integration: the learned cost model + active-learning tuner
//! (rust/docs/DESIGN.md §16). The acceptance criterion of ROADMAP item
//! 4(a): on resnet18/mlu100 the active tuner lands within 5% of the
//! reduced oracle DP's predicted latency while issuing strictly fewer
//! real cost-engine evaluations, and the whole stack — fit, save/load,
//! transfer — is deterministic and survives the tuner-registry surface.

use dlfusion::accel::{Simulator, Target};
use dlfusion::cost::CostEngine;
use dlfusion::learn::{collect_samples, ActiveTuner, FitConfig,
                      LearnedCostModel, TransferMatrix, FEATURE_DIM};
use dlfusion::tuner::{self, OracleDp, Tuner, TuningRequest};
use dlfusion::zoo;

#[test]
fn active_tuner_is_within_five_percent_of_the_oracle_with_fewer_evals() {
    let sim = Simulator::new(Target::mlu100());
    let model = zoo::resnet18();
    let request = TuningRequest::new(&sim, &model);
    // Fresh contexts: each backend starts cold, so its cache-miss count is
    // exactly the number of distinct real engine computations it forced.
    let active = request.run(&mut ActiveTuner::new()).expect("learned tune");
    let oracle = request.run(&mut OracleDp::reduced()).expect("oracle tune");
    assert!(active.predicted_ms <= oracle.predicted_ms * 1.05,
            "active {} ms vs oracle {} ms: over the 5% acceptance band",
            active.predicted_ms, oracle.predicted_ms);
    assert!(active.stats.cache_misses < oracle.stats.cache_misses,
            "active tuner must force strictly fewer real evaluations \
             ({} vs {})",
            active.stats.cache_misses, oracle.stats.cache_misses);
    assert!(active.stats.evals_saved > 0,
            "the pruning report must show savings");
    active.schedule
        .validate(model.num_layers(), sim.spec.num_cores)
        .expect("valid schedule");
}

#[test]
fn learned_backend_rides_the_registry_and_the_compare_panel() {
    let sim = Simulator::new(Target::mlu100());
    let model = zoo::resnet18();
    // Registry: both names resolve to the same backend.
    assert_eq!(tuner::backend_by_name("learned").unwrap().name(), "learned");
    assert_eq!(tuner::backend_by_name("active").unwrap().name(), "learned");
    // The comparison surface (one shared engine) accepts the backend and
    // reports its pruning next to the references.
    let request = TuningRequest::new(&sim, &model);
    let mut tuners: Vec<Box<dyn Tuner>> = vec![
        Box::new(tuner::Algorithm1),
        Box::new(OracleDp::reduced()),
        Box::new(ActiveTuner::new()),
    ];
    let cmp = request.compare(&mut tuners).expect("comparison");
    let learned = cmp.outcomes.iter().find(|o| o.tuner == "learned")
        .expect("learned row in the comparison");
    let oracle = cmp.outcomes.iter().find(|o| o.tuner.contains("oracle"))
        .expect("oracle row in the comparison");
    assert!(learned.predicted_ms <= oracle.predicted_ms * 1.05,
            "learned {} ms vs oracle {} ms in the shared-engine comparison",
            learned.predicted_ms, oracle.predicted_ms);
    assert!(learned.stats.evals_saved > 0);
    assert!(cmp.render("learned acceptance").contains("learned"));
}

#[test]
fn fit_save_load_predicts_identically() {
    let sim = Simulator::new(Target::mlu100());
    let model = zoo::resnet18();
    let engine = CostEngine::new(&sim, &model);
    let samples = collect_samples(&engine, &sim.spec.reduced_mp_set(), &[1]);
    assert!(samples.iter().all(|s| s.features.len() == FEATURE_DIM));
    let fitted =
        LearnedCostModel::fit("mlu100", &samples, &FitConfig::default())
            .expect("fit");
    assert!(fitted.report.r2_holdout > 0.7,
            "holdout r2 {}", fitted.report.r2_holdout);

    let dir = std::env::temp_dir().join("dlfusion_learned_cost_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.json");
    let path = path.to_str().unwrap();
    fitted.save(path).unwrap();
    let back = LearnedCostModel::load(path).unwrap();
    for s in samples.iter().step_by(17) {
        assert_eq!(fitted.predict_ms(&s.features).to_bits(),
                   back.predict_ms(&s.features).to_bits(),
                   "save/load must preserve predictions bit for bit");
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn transfer_matrix_spans_the_registry_with_a_sane_diagonal() {
    let model = zoo::resnet18();
    let t = TransferMatrix::build(&model, &FitConfig::default()).unwrap();
    let names: Vec<&str> = Target::NAMES.to_vec();
    assert_eq!(t.targets, names);
    for (r, train) in names.iter().enumerate() {
        assert_eq!(t.mape[r].len(), names.len());
        let diag = t.cell(train, train).unwrap();
        assert!(diag.is_finite() && diag >= 0.0);
        assert!(diag < 0.6,
                "in-target mape for {train} is {diag}: the model should \
                 at least fit its own hardware");
    }
    assert!(t.render().contains("transfer matrix"));
}
