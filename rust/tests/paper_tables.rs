//! Integration: pin the reproduction against the paper's printed numbers
//! (Tables I–II, Eq. 4, and the Fig. 10 qualitative claims).
#![allow(deprecated)] // exercises the legacy shims alongside the tuner API

use dlfusion::accel::{Simulator, Target};
use dlfusion::graph::LayerKind;
use dlfusion::optimizer::{run_strategy, space, Strategy};
use dlfusion::search;
use dlfusion::zoo;

#[test]
fn table1_hardware_spec() {
    let s = Target::mlu100().into_spec();
    assert_eq!(s.core_freq_ghz, 1.0);
    assert_eq!(s.peak_gflops(), 64_000.0); // 64 TFLOPS FP16
    assert_eq!(s.mem_bw_gbps, 102.4);
    assert_eq!(s.mem_bytes / (1u64 << 30) as f64, 8.0);
    assert_eq!(s.num_cores, 32);
}

#[test]
fn table2_network_statistics() {
    // (name, paper total GOPs, paper avg GOPs, paper conv count, tolerance)
    // MobileNet's total is checked under the dense-equivalent convention —
    // see zoo::mobilenet docs and EXPERIMENTS.md.
    let rows = [
        ("resnet18", 3.38, 0.169, 20, 0.15),
        ("resnet50", 7.61, 0.144, 53, 0.15),
        ("vgg19", 36.34, 2.27, 16, 0.15),
        ("alexnet", 1.22, 0.244, 5, 0.15),
    ];
    for (name, total, avg, count, tol) in rows {
        let m = zoo::by_name(name).unwrap();
        let s = m.stats();
        assert_eq!(s.num_conv, count, "{name} conv count");
        assert!((s.total_conv_gops - total).abs() / total < tol,
                "{name}: total {} vs paper {total}", s.total_conv_gops);
        assert!((s.avg_conv_gops - avg).abs() / avg < tol,
                "{name}: avg {} vs paper {avg}", s.avg_conv_gops);
    }
    // MobileNet: count exact; total under dense-equivalent Eq. 1.
    let m = zoo::mobilenet_v2();
    assert_eq!(m.stats().num_conv, 52);
    let dense: f64 = m.layers.iter().filter_map(|l| match &l.kind {
        LayerKind::Conv(c) => Some(c.op_gops_dense_equiv()),
        _ => None,
    }).sum();
    assert!((dense - 10.33).abs() / 10.33 < 0.25, "mobilenet dense-equiv {dense}");
}

#[test]
fn eq4_search_space_magnitude() {
    // "When n equals 50, there are 8.17 x 10^75 possible combinations."
    let s = space::search_space(50, 32);
    assert!(s.exp10 >= 75 && s.exp10 <= 76, "Space(50) = {s}");
    // And the exact closed form matches enumeration for small n.
    for n in 2..=8 {
        assert_eq!(space::search_space_exact(n, 32), space::enumerate_space(n, 32));
    }
}

#[test]
fn fig10_speedup_claims() {
    // Paper: DLFusion achieves 3.6x–7.9x over the non-optimized baseline
    // and is close to the oracle. Our simulator reproduces the shape; the
    // per-network values and documented deviations live in EXPERIMENTS.md.
    let sim = Simulator::new(Target::mlu100());
    let mut speedups = Vec::new();
    for m in zoo::all_models() {
        let (_, base) = run_strategy(&sim, &m, Strategy::NonOptimization);
        let (_, dlf) = run_strategy(&sim, &m, Strategy::DlFusion);
        let (oracle_sched, _) = search::oracle_schedule(&sim, &m);
        let t_oracle = sim.run_schedule(&m, &oracle_sched).total_ms;
        let oracle_fps = 1000.0 / t_oracle;
        let speedup = dlf.fps() / base.fps();
        speedups.push((m.name.clone(), speedup, dlf.fps() / oracle_fps));
    }
    // Band: every model gains substantially; the best models land in the
    // paper's 3.6–7.9 range.
    let max = speedups.iter().map(|s| s.1).fold(0.0, f64::max);
    let min = speedups.iter().map(|s| s.1).fold(f64::MAX, f64::min);
    assert!(max > 6.0 && max < 10.0, "max speedup {max}");
    assert!(min > 1.5, "min speedup {min}");
    // Oracle proximity: geometric-mean ratio >= 0.80 (paper: >= 0.9 on
    // their hardware; our oracle is an exact DP, strictly stronger than
    // the paper's sampled brute force).
    let gm = dlfusion::stats::descriptive::geomean(
        &speedups.iter().map(|s| s.2).collect::<Vec<_>>());
    assert!(gm >= 0.80, "oracle-proximity geomean {gm}: {speedups:?}");
}

#[test]
fn fig10_vgg_benefits_most_from_mp_resnet_mobilenet_from_fusion() {
    // The paper's two observations about model classes.
    let sim = Simulator::new(Target::mlu100());
    let mp_gain = |name: &str| {
        let m = zoo::by_name(name).unwrap();
        let (_, base) = run_strategy(&sim, &m, Strategy::NonOptimization);
        let (_, s3) = run_strategy(&sim, &m, Strategy::DynamicMp);
        s3.fps() / base.fps()
    };
    let fusion_gain = |name: &str| {
        let m = zoo::by_name(name).unwrap();
        let (_, s3) = run_strategy(&sim, &m, Strategy::DynamicMp);
        let (_, s6) = run_strategy(&sim, &m, Strategy::DlFusion);
        s6.fps() / s3.fps()
    };
    // High-op-count-per-layer VGG gains more from MP than low-op ResNet.
    assert!(mp_gain("vgg19") > mp_gain("resnet18"),
            "vgg {} vs resnet {}", mp_gain("vgg19"), mp_gain("resnet18"));
    // Low-op-count models gain more from fusion on top of MP.
    assert!(fusion_gain("mobilenet") > fusion_gain("vgg19"),
            "mobilenet {} vs vgg {}", fusion_gain("mobilenet"), fusion_gain("vgg19"));
}

#[test]
fn oracle_within_reduced_space_definition() {
    // Strategy 7 obeys both paper reductions on every model.
    let sim = Simulator::new(Target::mlu100());
    for m in zoo::all_models() {
        let (sched, _) = search::oracle_schedule(&sim, &m);
        let allowed = sim.spec.reduced_mp_set();
        for (i, b) in sched.blocks.iter().enumerate() {
            assert!(allowed.contains(&b.mp), "{}: mp {}", m.name, b.mp);
            let last = i == sched.blocks.len() - 1;
            assert!(b.len() % 4 == 0 || last, "{}: block len {}", m.name, b.len());
        }
    }
}
