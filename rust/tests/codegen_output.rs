//! Integration: generated C++ structure across models and schedules, plus
//! `.dlm` round-trips feeding codegen.

use dlfusion::accel::{Simulator, Target};
use dlfusion::codegen::{generate_cpp, generate_header};
use dlfusion::graph::format::{from_dlm, to_dlm};
use dlfusion::optimizer::{self, Schedule};
use dlfusion::zoo;

#[test]
fn full_pipeline_dlm_to_cpp() {
    let sim = Simulator::new(Target::mlu100());
    for m in zoo::all_models() {
        // Round-trip through .dlm first (the paper's ONNX entry path).
        let text = to_dlm(&m);
        let model = from_dlm(&text).unwrap();
        let sched = optimizer::dlfusion_schedule(&model, &sim.spec);
        let cpp = generate_cpp(&model, &sched);

        // Every layer created exactly once.
        assert_eq!(cpp.matches("cnmlCreateOperator(").count(), model.num_layers(),
                   "{}", model.name);
        // Every block compiled exactly once with its MP.
        let compiles = cpp.matches("cnmlCompileOperator(").count()
            + cpp.matches("cnmlCompileFusionOperator(").count();
        assert_eq!(compiles, sched.num_blocks(), "{}", model.name);
        // Forward calls match block count.
        let forwards = cpp.matches("cnmlComputeOperatorForward(").count()
            + cpp.matches("cnmlComputeFusionOperatorForward(").count();
        assert_eq!(forwards, sched.num_blocks(), "{}", model.name);
        // MP values surface in the emitted code.
        for b in &sched.blocks {
            assert!(cpp.contains(&format!("/*Model_Parallelism=*/{}", b.mp)),
                    "{}: missing MP {}", model.name, b.mp);
        }
    }
}

#[test]
fn header_is_self_contained_cpp() {
    let h = generate_header();
    assert!(h.contains("#pragma once"));
    // No unresolved external symbols: all functions inline.
    for line in h.lines() {
        if line.contains("cnmlStatus_t cnml") {
            assert!(line.trim_start().starts_with("inline"), "{line}");
        }
    }
}

#[test]
fn schedule_variants_change_emission_shape() {
    let m = zoo::mini_cnn();
    let layerwise = generate_cpp(&m, &Schedule::layerwise(m.num_layers(), 1));
    let fused = generate_cpp(&m, &Schedule::single_block(m.num_layers(), 32));
    assert!(layerwise.len() < fused.len() + 4096); // both reasonable sizes
    assert!(!layerwise.contains("FusionOperator"));
    assert!(fused.contains("cnmlComputeFusionOperatorForward(fusion_0)"));
    assert_eq!(fused.matches("cnmlFuseOperator(").count(), m.num_layers());
}

#[test]
fn generated_files_via_cli_paths() {
    // Mirror what `dlfusion codegen` writes, into a temp dir.
    let dir = std::env::temp_dir().join("dlfusion_codegen_test");
    std::fs::create_dir_all(&dir).unwrap();
    let m = zoo::alexnet();
    let sim = Simulator::new(Target::mlu100());
    let sched = optimizer::dlfusion_schedule(&m, &sim.spec);
    let cpp_path = dir.join("alexnet_inference.cpp");
    std::fs::write(&cpp_path, generate_cpp(&m, &sched)).unwrap();
    std::fs::write(dir.join("cnml_compat.h"), generate_header()).unwrap();
    let body = std::fs::read_to_string(&cpp_path).unwrap();
    assert!(body.contains("#include \"cnml_compat.h\""));
    assert!(body.contains("int main()"));
}
