//! Bit-identical parallelism (rust/docs/DESIGN.md §12): the parallel sweep
//! driver and the threaded comparison must return exactly what their
//! sequential counterparts return — same schedules, same f64 bits, same
//! evaluation and cache-miss counts — for the full zoo across the target
//! registry. Threads buy wall time, never a different answer.

use dlfusion::accel::{Simulator, Target};
use dlfusion::tuner::{self, SweepJob, Tuner};
use dlfusion::zoo;

#[test]
fn full_zoo_sweep_is_bit_identical_across_thread_counts() {
    let models = zoo::all_models();
    let targets = [Target::mlu100(), Target::edge4(), Target::hbm32()];
    let backends = ["algorithm1", "oracle"];
    let jobs: Vec<SweepJob<'_>> = models
        .iter()
        .flat_map(|m| {
            targets.iter().flat_map(move |t| {
                backends
                    .iter()
                    .map(move |b| SweepJob::new(m, t.clone(), b))
            })
        })
        .collect();
    assert_eq!(jobs.len(), models.len() * targets.len() * backends.len());

    let seq = tuner::run_sweep(&jobs, 1);
    let par = tuner::run_sweep(&jobs, 4);
    assert_eq!(seq.len(), par.len());
    for (s, p) in seq.iter().zip(&par) {
        let label = format!("{} on {} via {}",
                            s.job.model.name, s.job.target.name(), s.job.backend);
        let s = s.result.as_ref().unwrap_or_else(|e| panic!("{label}: {e}"));
        let p = p.result.as_ref().unwrap_or_else(|e| panic!("{label}: {e}"));
        assert_eq!(s.schedule, p.schedule, "{label}: schedule");
        assert_eq!(s.predicted_ms.to_bits(), p.predicted_ms.to_bits(),
                   "{label}: predicted_ms");
        assert_eq!(s.batch, p.batch, "{label}: batch");
        assert_eq!(s.stats.evaluations, p.stats.evaluations,
                   "{label}: evaluations");
        assert_eq!(s.stats.cache_misses, p.stats.cache_misses,
                   "{label}: cache_misses");
    }
}

#[test]
fn batched_sweep_is_bit_identical_across_thread_counts() {
    let model = zoo::resnet18();
    let jobs: Vec<SweepJob<'_>> = [1usize, 2, 4, 8]
        .iter()
        .map(|&b| {
            SweepJob::new(&model, Target::mlu100(), "oracle").batches(vec![b])
        })
        .collect();
    let seq = tuner::run_sweep(&jobs, 1);
    let par = tuner::run_sweep(&jobs, 4);
    for (s, p) in seq.iter().zip(&par) {
        let s = s.result.as_ref().unwrap();
        let p = p.result.as_ref().unwrap();
        assert_eq!(s.batch, p.batch);
        assert_eq!(s.schedule, p.schedule);
        assert_eq!(s.predicted_ms.to_bits(), p.predicted_ms.to_bits());
    }
}

#[test]
fn threaded_comparison_matches_sequential_outcomes_and_engine_totals() {
    let sim = Simulator::new(Target::mlu100());
    let model = zoo::resnet18();

    let run = |threads: usize| {
        let request = tuner::TuningRequest::new(&sim, &model).threads(threads);
        let mut tuners: Vec<Box<dyn Tuner>> = vec![
            Box::new(tuner::Algorithm1),
            Box::new(tuner::OracleDp::reduced()),
            Box::new(tuner::OracleDp::constrained()),
            Box::new(tuner::Annealer::new()),
        ];
        request.compare(&mut tuners).expect("comparison")
    };
    let seq = run(1);
    let par = run(4);

    assert_eq!(seq.outcomes.len(), par.outcomes.len());
    for (s, p) in seq.outcomes.iter().zip(&par.outcomes) {
        assert_eq!(s.tuner, p.tuner);
        assert_eq!(s.schedule, p.schedule, "{}: schedule", s.tuner);
        assert_eq!(s.predicted_ms.to_bits(), p.predicted_ms.to_bits(),
                   "{}: predicted_ms", s.tuner);
        assert_eq!(s.stats.evaluations, p.stats.evaluations,
                   "{}: evaluations", s.tuner);
    }
    // Merged engine totals: the shard-locked cache computes every distinct
    // key exactly once no matter which worker gets there first, so the
    // whole-comparison hit/miss totals are identical too (only the
    // per-tuner *attribution* of a shared first-miss may move).
    assert_eq!(seq.engine_stats.misses, par.engine_stats.misses);
    assert_eq!(seq.engine_stats.hits + seq.engine_stats.misses,
               par.engine_stats.hits + par.engine_stats.misses);
}

/// The observability determinism contract (rust/docs/DESIGN.md §14): the
/// deterministic (sim-domain) half of a tuning run's metrics snapshot is a
/// pure function of the request — `--threads` buys wall time, never a
/// different snapshot. Only the wall domain may move between runs.
#[test]
fn sim_domain_metrics_snapshot_is_thread_invariant() {
    use dlfusion::obs::{Domain, MetricsRegistry};

    let sim = Simulator::new(Target::mlu100());
    let model = zoo::resnet18();
    let snap = |threads: usize| {
        let request = tuner::TuningRequest::new(&sim, &model).threads(threads);
        let mut cx = request.context();
        let outcome = tuner::OracleDp::reduced().tune(&mut cx).expect("tune");
        let mut reg = MetricsRegistry::new();
        outcome.export_metrics(&mut reg);
        cx.engine().export_metrics(&mut reg);
        reg.domain_json(Domain::Sim).to_string()
    };
    let seq = snap(1);
    let par = snap(4);
    assert_eq!(seq, par,
               "deterministic metrics must not depend on thread count");
    // And the same snapshot again at the same thread count: run-to-run
    // identical, byte for byte.
    assert_eq!(par, snap(4));
}

/// Serving runs entirely on the event clock, so both its Chrome trace
/// export and its metrics snapshot — wall section included, because it is
/// empty — are bit-identical from run to run.
#[test]
fn serving_trace_and_metrics_exports_are_run_to_run_identical() {
    use dlfusion::obs::MetricsRegistry;
    use dlfusion::serving::{self, AllocationRequest, ArrivalProcess,
                            ClusterConfig, DispatchPolicy, ModelMix,
                            SimulationRun, SloReport};

    let sim = Simulator::new(Target::mlu100());
    let run_once = || {
        let mix = ModelMix::uniform(vec![zoo::resnet18(), zoo::alexnet()]);
        let plan = AllocationRequest::new(&sim, &mix)
            .slo_ms(Some(50.0))
            .plan()
            .expect("plan");
        let trace = serving::generate_trace(
            &mix, ArrivalProcess::OpenPoisson { rate_rps: 400.0 }, 128, 7);
        let cfg = ClusterConfig { num_cores: sim.spec.num_cores,
                                  policy: DispatchPolicy::Fifo };
        let services = plan.services(true);
        let result = SimulationRun::new(&cfg, &services)
            .trace(&trace)
            .run()
            .expect("simulate");
        let session = serving::sim_trace(&result, &services, "parity");
        let mut reg = MetricsRegistry::new();
        SloReport::from_sim(&result, Some(50.0)).export_metrics(&mut reg);
        (session.to_chrome_string(), reg.snapshot().to_string())
    };
    let (trace_a, snap_a) = run_once();
    let (trace_b, snap_b) = run_once();
    assert_eq!(trace_a, trace_b,
               "chrome trace export must be bit-identical run to run");
    assert_eq!(snap_a, snap_b,
               "metrics snapshot must be bit-identical run to run");
    assert!(trace_a.contains("traceEvents"));
}

/// The learned stack's determinism contract (rust/docs/DESIGN.md §16):
/// fit coefficients, the transfer matrix, and the active tuner's schedule
/// are pure functions of the request — bit-identical across runs and
/// across `--threads` settings (the walk is sequential by construction, so
/// the thread knob must change nothing).
#[test]
fn learned_stack_is_bit_identical_across_runs_and_threads() {
    use dlfusion::cost::CostEngine;
    use dlfusion::learn::{collect_samples, ActiveTuner, FitConfig,
                          LearnedCostModel, TransferMatrix};

    let sim = Simulator::new(Target::mlu100());
    let model = zoo::resnet18();

    // Fit: same samples, same config => same coefficient bits.
    let fit_once = || {
        let engine = CostEngine::new(&sim, &model);
        let samples =
            collect_samples(&engine, &sim.spec.reduced_mp_set(), &[1]);
        LearnedCostModel::fit("mlu100", &samples, &FitConfig::default())
            .expect("fit")
    };
    let a = fit_once();
    let b = fit_once();
    assert_eq!(a.bias.to_bits(), b.bias.to_bits());
    assert_eq!(a.residual_band.to_bits(), b.residual_band.to_bits());
    for (x, y) in a.weights.iter().zip(&b.weights) {
        assert_eq!(x.to_bits(), y.to_bits(), "fit weights must be stable");
    }

    // Transfer matrix: every cell run-to-run identical.
    let ta = TransferMatrix::build(&model, &FitConfig::default()).unwrap();
    let tb = TransferMatrix::build(&model, &FitConfig::default()).unwrap();
    for (ra, rb) in ta.mape.iter().zip(&tb.mape) {
        for (x, y) in ra.iter().zip(rb) {
            assert_eq!(x.to_bits(), y.to_bits(), "transfer cell moved");
        }
    }

    // Active tuner: schedule, latency bits, and pruning accounting are
    // invariant across runs and thread counts.
    let tune_once = |threads: usize| {
        let request =
            tuner::TuningRequest::new(&sim, &model).threads(threads);
        request.run(&mut ActiveTuner::new()).expect("learned tune")
    };
    let s1 = tune_once(1);
    let s1b = tune_once(1);
    let s4 = tune_once(4);
    for other in [&s1b, &s4] {
        assert_eq!(s1.schedule, other.schedule, "learned schedule moved");
        assert_eq!(s1.predicted_ms.to_bits(), other.predicted_ms.to_bits());
        assert_eq!(s1.stats.evaluations, other.stats.evaluations);
        assert_eq!(s1.stats.cache_misses, other.stats.cache_misses);
        assert_eq!(s1.stats.evals_saved, other.stats.evals_saved);
    }
}
