//! Integration: the explicit hardware-target API (rust/docs/DESIGN.md §11).
//!
//! Four surfaces are pinned: the registry + builder validation, bit-exact
//! default-target parity (the `mlu100` registry entry must reproduce the
//! pre-redesign spec literal, and every tuner backend must return identical
//! results through the `Target` construction path), cross-target divergence
//! (the optimal (MP, fusion) point really is a function of the hardware),
//! and the serving-side mixed-target guard.

use dlfusion::accel::{AcceleratorSpec, Simulator, SpecBuilder, Target, TargetError};
use dlfusion::serving::{self, ClusterConfig, DispatchPolicy, ModelMix, ModelService};
use dlfusion::tuner::{compare_targets, Algorithm1, Annealer, OracleDp,
                      TableStrategy, Tuner, TuningError, TuningRequest};
use dlfusion::optimizer::Strategy;
use dlfusion::zoo;

/// The pre-redesign `AcceleratorSpec::mlu100()` literal, written out in
/// full: the registry's default target must never drift from it, because
/// every pinned result in the repo (tuner parity, paper tables, serving
/// traces) is calibrated against these numbers.
fn mlu100_literal() -> AcceleratorSpec {
    AcceleratorSpec {
        name: "MLU100-C3".to_string(),
        num_cores: 32,
        peak_gflops_per_core: 2000.0,
        mem_bw_gbps: 102.4,
        mem_bytes: 8.0 * 1024.0 * 1024.0 * 1024.0,
        core_freq_ghz: 1.0,
        fill_gops: 10f64.powf(1.25) / 9.0 / 32.0,
        channel_granularity: 4,
        launch_overhead_us: 20.0,
        sync_us_per_core: 5.0,
        fused_layer_us: 4.0,
        core_buffer_bytes: 2.0 * 1024.0 * 1024.0,
    }
}

#[test]
fn registry_lookup_and_unknown_name_error() {
    assert_eq!(Target::NAMES, &["mlu100", "mlu270", "edge4", "hbm32"]);
    for &name in Target::NAMES {
        assert_eq!(Target::by_name(name).unwrap().name(), name);
    }
    let err = Target::by_name("tpu-v9").unwrap_err();
    match &err {
        TargetError::UnknownTarget { name } => assert_eq!(name, "tpu-v9"),
        other => panic!("expected UnknownTarget, got {other:?}"),
    }
    // The error message teaches the registry.
    let msg = err.to_string();
    for &name in Target::NAMES {
        assert!(msg.contains(name), "{msg}");
    }
    let all = Target::all();
    assert!(all.len() >= 4);
    assert_eq!(all[0].name(), "mlu100", "the default target leads the registry");
}

#[test]
fn builder_validation_error_paths() {
    let cases: Vec<(SpecBuilder, &str)> = vec![
        (SpecBuilder::new("x").num_cores(0), "num_cores"),
        (SpecBuilder::new("x").mem_bw_gbps(0.0), "mem_bw_gbps"),
        (SpecBuilder::new("x").peak_gflops_per_core(-1.0), "peak_gflops_per_core"),
        (SpecBuilder::new("x").channel_granularity(0), "channel_granularity"),
        (SpecBuilder::new("x").channel_granularity(100_000), "channel_granularity"),
        (SpecBuilder::new("x").core_buffer_bytes(1.0), "core_buffer_bytes"),
        (SpecBuilder::new("x").fill_gops(f64::NAN), "fill_gops"),
        (SpecBuilder::new("x").launch_overhead_us(-3.0), "launch_overhead_us"),
    ];
    for (builder, expect_field) in cases {
        match builder.build() {
            Err(TargetError::InvalidSpec { field, .. }) => {
                assert_eq!(field, expect_field)
            }
            other => panic!("expected InvalidSpec({expect_field}), got {other:?}"),
        }
    }
    // The happy path: only named fields differ from the mlu100 calibration.
    let spec = SpecBuilder::new("TwoCore")
        .num_cores(2)
        .mem_bw_gbps(51.2)
        .build()
        .unwrap();
    assert_eq!(spec.num_cores, 2);
    assert_eq!(spec.mem_bw_gbps, 51.2);
    assert_eq!(spec.channel_granularity, mlu100_literal().channel_granularity);
    // And it wraps into a custom target usable everywhere a registry one is.
    let target = Target::custom("two", "test point", spec).unwrap();
    assert_eq!(Simulator::new(target).target(), "two");
}

#[test]
fn default_target_spec_is_bit_identical_to_the_pre_redesign_literal() {
    assert_eq!(*Target::mlu100().spec(), mlu100_literal());
}

/// Every backend must produce bit-identical outcomes whether the simulator
/// came from the registry or from the raw pre-redesign spec literal — the
/// redesign changed how hardware is named, not what any number is.
#[test]
fn default_target_tuner_parity_across_construction_paths() {
    let via_target = Simulator::new(Target::mlu100());
    let via_spec = Simulator::from_spec(mlu100_literal()).expect("literal validates");
    let mut backends: Vec<Box<dyn Tuner>> = vec![
        Box::new(Algorithm1),
        Box::new(OracleDp::reduced()),
        Box::new(Annealer::new()),
    ];
    for st in Strategy::ALL {
        backends.push(Box::new(TableStrategy(st)));
    }
    for model in [zoo::resnet18(), zoo::alexnet()] {
        for backend in backends.iter_mut() {
            let a = TuningRequest::new(&via_target, &model)
                .run(backend.as_mut())
                .unwrap();
            let b = TuningRequest::new(&via_spec, &model)
                .run(backend.as_mut())
                .unwrap();
            assert_eq!(a.schedule, b.schedule, "{} {}", model.name, a.tuner);
            assert_eq!(a.predicted_ms, b.predicted_ms, "{} {}", model.name, a.tuner);
        }
    }
}

/// The paper's premise, pinned: the oracle's optimal (MP, fusion) point for
/// resnet18 differs between the edge-class target and the MLU100.
#[test]
fn optimal_schedule_diverges_across_targets() {
    let model = zoo::resnet18();
    let tune_on = |target: Target| {
        let sim = Simulator::new(target);
        TuningRequest::new(&sim, &model)
            .run(&mut OracleDp::reduced())
            .unwrap()
    };
    let mlu100 = tune_on(Target::mlu100());
    let edge = tune_on(Target::edge4());
    assert_ne!(mlu100.schedule, edge.schedule,
               "hardware changed but the optimal schedule did not");
    // The edge part can never schedule past its 4 cores, while the MLU100
    // optimum uses more than 4 somewhere on resnet18.
    let max_mp = |s: &dlfusion::optimizer::Schedule| {
        s.blocks.iter().map(|b| b.mp).max().unwrap()
    };
    assert!(max_mp(&edge.schedule) <= 4);
    assert!(max_mp(&mlu100.schedule) > 4);
    // Same model, weaker chip: predicted latency is strictly worse.
    assert!(edge.predicted_ms > mlu100.predicted_ms);
}

#[test]
fn compare_targets_runs_the_registry_and_ranks_hardware() {
    let model = zoo::alexnet();
    let sim = Simulator::new(Target::mlu100());
    let template = TuningRequest::new(&sim, &model);
    let targets = Target::all();
    let cmp = compare_targets(&model, &targets, &mut Algorithm1, &template).unwrap();
    assert_eq!(cmp.rows.len(), targets.len());
    assert!(cmp.rows.len() >= 3);
    assert!(cmp.skipped.is_empty());
    for (row, target) in cmp.rows.iter().zip(&targets) {
        assert_eq!(row.target.name(), target.name());
        assert!(row.outcome.predicted_ms > 0.0);
        let max_mp = row.outcome.schedule.blocks.iter().map(|b| b.mp).max().unwrap();
        assert!(max_mp <= target.spec().num_cores);
    }
    // The edge part is the slowest hardware point for a conv net.
    let best = cmp.best().unwrap();
    assert_ne!(best.target.name(), "edge4");
    let rendered = cmp.render("cross-target");
    for &name in Target::NAMES {
        assert!(rendered.contains(name), "{rendered}");
    }
}

/// A knob that is invalid on one chip (MP 8 on the 4-core edge part) must
/// not abort the whole cross-target run: the bad target is skipped with a
/// per-target error and the rest still compare.
#[test]
fn compare_targets_skips_targets_the_knobs_do_not_fit() {
    let model = zoo::alexnet();
    let sim = Simulator::new(Target::mlu100());
    let template = TuningRequest::new(&sim, &model).mp_candidates(vec![8]);
    let targets = Target::all();
    let cmp = compare_targets(&model, &targets, &mut OracleDp::constrained(),
                              &template)
        .unwrap();
    let skipped: Vec<&str> = cmp.skipped.iter().map(|(t, _)| t.name()).collect();
    assert_eq!(skipped, vec!["edge4"], "{skipped:?}");
    assert_eq!(cmp.rows.len(), targets.len() - 1);
    assert!(matches!(&cmp.skipped[0].1,
                     TuningError::InvalidMp { mp: 8, num_cores: 4 }));
    let rendered = cmp.render("partial");
    assert!(rendered.contains("edge4: skipped"), "{rendered}");

    // Only when *every* target fails does the comparison error, and the
    // error names the first failing target.
    let template = TuningRequest::new(&sim, &model).mp_candidates(vec![999]);
    let err = compare_targets(&model, &targets, &mut OracleDp::constrained(),
                              &template)
        .unwrap_err();
    assert!(err.to_string().contains("mlu100"), "{err}");
}

#[test]
fn reduced_mp_set_follows_the_target() {
    assert_eq!(Target::mlu100().spec().reduced_mp_set(),
               vec![1, 2, 4, 8, 12, 16, 24, 32]);
    assert_eq!(Target::mlu270().spec().reduced_mp_set(),
               vec![1, 2, 4, 8, 12, 16, 24, 32, 48, 64]);
    assert_eq!(Target::edge4().spec().reduced_mp_set(), vec![1, 2, 4]);
}

#[test]
fn tuning_request_and_serving_plan_record_their_target() {
    let sim = Simulator::new(Target::edge4());
    let model = zoo::alexnet();
    let request = TuningRequest::new(&sim, &model);
    assert_eq!(request.target(), "edge4");
    assert_eq!(request.context().target(), "edge4");

    let mix = ModelMix::uniform(vec![zoo::alexnet()]);
    let plan = serving::AllocationRequest::new(&sim, &mix).plan().unwrap();
    assert_eq!(plan.target, "edge4");
    assert!(plan.render().contains("edge4"));
    for svc in plan.services(true) {
        assert_eq!(svc.target, "edge4");
    }
}

#[test]
fn cluster_rejects_services_planned_for_different_targets() {
    let mix = ModelMix::uniform(vec![zoo::alexnet()]);
    let trace = serving::generate_trace(
        &mix, serving::ArrivalProcess::OpenPoisson { rate_rps: 100.0 }, 16, 7);

    let sim_a = Simulator::new(Target::mlu100());
    let sim_b = Simulator::new(Target::edge4());
    let plan_a = serving::AllocationRequest::new(&sim_a, &mix).plan().unwrap();
    let plan_b = serving::AllocationRequest::new(&sim_b, &mix).plan().unwrap();
    let mut services = plan_a.services(true);
    let mut foreign = plan_b.services(true);
    foreign[0].name = "alexnet_edge".to_string();
    services.append(&mut foreign);

    let cfg = ClusterConfig { num_cores: sim_a.spec.num_cores,
                              policy: DispatchPolicy::Fifo };
    let err = serving::SimulationRun::new(&cfg, &services)
        .trace(&trace)
        .run()
        .unwrap_err();
    assert!(err.contains("mixes hardware targets"), "{err}");
    assert!(err.contains("mlu100") && err.contains("edge4"), "{err}");

    // Homogeneous plans still simulate, and hand-built services with no
    // recorded target stay compatible with planned ones.
    let ok = serving::SimulationRun::new(&cfg, &plan_a.services(true))
        .trace(&trace)
        .run();
    assert!(ok.is_ok());
    let mut services = plan_a.services(true);
    services.push(ModelService::new("adhoc", 1, 1.0));
    // A second model index is required for the extra service to be valid
    // in a trace, so just validate the target check by reusing the trace
    // over model index 0 only.
    let ok = serving::SimulationRun::new(&cfg, &services).trace(&trace).run();
    assert!(ok.is_ok(), "{ok:?}");
}

/// The bandwidth-rich hypothetical exists to expose hardware sensitivity:
/// with ~10x the bandwidth, memory-bound blocks get cheaper, so the chip
/// serves the same tuned model strictly faster.
#[test]
fn bandwidth_rich_target_is_strictly_faster_on_vgg() {
    let model = zoo::vgg19();
    let on = |target: Target| {
        let sim = Simulator::new(target);
        TuningRequest::new(&sim, &model)
            .run(&mut OracleDp::reduced())
            .unwrap()
            .predicted_ms
    };
    assert!(on(Target::hbm32()) < on(Target::mlu100()));
}
