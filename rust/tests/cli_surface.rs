//! Integration: the CLI surface (arg parsing through command dispatch).
//! Commands run in-process via `cli::commands::run`, so these double as
//! smoke tests for the whole library stack.

use dlfusion::cli::args::Args;
use dlfusion::cli::commands;

fn run(line: &str) -> i32 {
    let args = Args::parse(line.split_whitespace().map(String::from)).unwrap();
    commands::run(&args)
}

#[test]
fn help_succeeds() {
    assert_eq!(run("help"), 0);
}

#[test]
fn zoo_listing_succeeds() {
    assert_eq!(run("zoo"), 0);
    assert_eq!(run("zoo --spec"), 0);
    assert_eq!(run("zoo --spec --target edge4"), 0);
}

#[test]
fn targets_command_lists_the_registry() {
    assert_eq!(run("targets"), 0);
}

#[test]
fn target_flag_selects_registry_hardware() {
    assert_eq!(run("tune alexnet --target edge4"), 0);
    assert_eq!(run("tune alexnet --target hbm32 --tuner oracle"), 0);
    assert_eq!(run("simulate alexnet --target mlu270"), 0);
    assert_eq!(run("search alexnet --target edge4 --iterations 100"), 0);
    assert_eq!(run("optimize alexnet --target mlu270"), 0);
    assert_eq!(run("trace alexnet --target edge4"), 0);
}

#[test]
fn threads_flag_fans_tuning_and_rejects_zero() {
    assert_eq!(run("tune resnet18 --tuner oracle --threads 4"), 0);
    assert_eq!(run("tune alexnet --compare --threads 4"), 0);
    assert_eq!(run("tune alexnet --compare-targets --threads 4"), 0);
    assert_eq!(run("tune alexnet --threads 0"), 1);
    assert_eq!(run("tune alexnet --threads abc"), 1);
    assert_eq!(run("tune alexnet --threads"), 1);
}

#[test]
fn serve_sim_no_events_keeps_the_report() {
    assert_eq!(run("serve-sim --models alexnet --requests 64 --rate 400 \
                    --no-events"), 0);
}

#[test]
fn target_flag_rejects_unknown_and_bare_forms() {
    // Unknown registry name → usage error on every threaded command.
    assert_eq!(run("tune alexnet --target tpu9"), 1);
    assert_eq!(run("simulate alexnet --target tpu9"), 1);
    assert_eq!(run("serve-sim --models alexnet --target tpu9"), 1);
    assert_eq!(run("perf-smoke --target tpu9"), 1);
    // Recording a non-default target's numbers under the mlu100 baseline
    // keys is refused, not advisory.
    assert_eq!(run("perf-smoke --target edge4 --write-baseline \
                    --out /tmp/dlfusion_cli_edge_smoke.json"), 1);
    // A trailing --target with no value is a parse error, not a panic and
    // not a lookup of the literal string "true".
    assert_eq!(run("tune alexnet --target"), 1);
    assert_eq!(run("serve-sim --models"), 1);
    assert_eq!(run("tune alexnet --target --tuner oracle"), 1);
}

#[test]
fn tune_compare_targets_renders_the_cross_target_table() {
    assert_eq!(run("tune alexnet --compare-targets"), 0);
    assert_eq!(run("tune resnet18 --compare-targets --tuner oracle"), 0);
    assert_eq!(run("tune alexnet --compare-targets --mps 1,2,4"), 0);
    // A knob invalid on one chip (MP 8 on the 4-core edge part) skips that
    // target instead of aborting the whole comparison.
    assert_eq!(
        run("tune alexnet --compare-targets --tuner oracle-constrained --mps 8"),
        0);
    // Backend and flag errors still surface cleanly.
    assert_eq!(run("tune alexnet --compare-targets --tuner bogus"), 1);
    assert_eq!(run("tune alexnet --compare-targets --mps abc"), 1);
    // The two comparison modes answer different questions; asking for both
    // at once is an explicit error rather than a silent pick.
    assert_eq!(run("tune alexnet --compare --compare-targets"), 1);
    // Exhaustive on a big model errors on the first target, cleanly.
    assert_eq!(run("tune resnet18 --compare-targets --tuner exhaustive"), 1);
}

#[test]
fn optimize_each_known_model() {
    for m in ["resnet18", "alexnet", "mini_cnn"] {
        assert_eq!(run(&format!("optimize {m}")), 0, "{m}");
    }
}

#[test]
fn optimize_with_strategy_and_critical() {
    assert_eq!(run("optimize alexnet --strategy 7"), 0);
    assert_eq!(run("optimize alexnet --critical 2.5"), 0);
}

#[test]
fn optimize_rejects_unknown_model_and_strategy() {
    assert_eq!(run("optimize not_a_net"), 1);
    assert_eq!(run("optimize alexnet --strategy 9"), 1);
    assert_eq!(run("optimize alexnet --strategy abc"), 1);
}

#[test]
fn simulate_prints_table() {
    assert_eq!(run("simulate alexnet"), 0);
}

#[test]
fn tune_single_backends() {
    assert_eq!(run("tune alexnet"), 0);
    assert_eq!(run("tune alexnet --tuner oracle"), 0);
    assert_eq!(run("tune alexnet --tuner strategy3"), 0);
    assert_eq!(run("tune alexnet --tuner anneal --iterations 100"), 0);
    assert_eq!(run("tune mini_cnn --tuner exhaustive"), 0);
    assert_eq!(run("tune alexnet --tuner oracle-constrained --mps 1,2,4"), 0);
}

#[test]
fn tune_compare_prints_side_by_side() {
    assert_eq!(run("tune alexnet --compare --iterations 100"), 0);
    // An explicit --tuner joins the default comparison panel.
    assert_eq!(run("tune mini_cnn --compare --tuner exhaustive --iterations 100"), 0);
    // Duplicating a default panel member is harmless.
    assert_eq!(run("tune alexnet --compare --tuner anneal --iterations 100"), 0);
}

#[test]
fn tune_rejects_bad_requests() {
    assert_eq!(run("tune nope_net"), 1);
    assert_eq!(run("tune alexnet --tuner bogus"), 1);
    assert_eq!(run("tune alexnet --tuner strategy9"), 1);
    assert_eq!(run("tune alexnet --mps abc"), 1);
    assert_eq!(run("tune alexnet --granularity huge"), 1);
    // Exhaustive on a large model is a clean error, not a panic.
    assert_eq!(run("tune resnet18 --tuner exhaustive"), 1);
    // A binding evaluation budget surfaces as an error for the DP.
    assert_eq!(run("tune alexnet --tuner oracle --budget-evals 3"), 1);
}

#[test]
fn search_command_reports_stats() {
    assert_eq!(run("search alexnet --iterations 100"), 0);
    assert_eq!(run("search nope_net"), 1);
    assert_eq!(run("search alexnet --iterations abc"), 1);
}

#[test]
fn space_command() {
    assert_eq!(run("space 50"), 0);
    assert_eq!(run("space 1"), 1);
    assert_eq!(run("space nope"), 1);
}

#[test]
fn trace_command() {
    assert_eq!(run("trace alexnet"), 0);
    assert_eq!(run("trace alexnet --strategy 1"), 0);
    assert_eq!(run("trace nope_net"), 1);
}

#[test]
fn serve_sim_happy_paths() {
    assert_eq!(
        run("serve-sim --models alexnet,mini_cnn --requests 48 --rate 500 \
             --slo-ms 50 --seed 3"),
        0);
    assert_eq!(
        run("serve-sim --models mini_cnn --arrivals closed --concurrency 16 \
             --requests 32 --policy sjf"),
        0);
    assert_eq!(
        run("serve-sim --models alexnet --arrivals bursty --rate 300 \
             --requests 40 --allocator single"),
        0);
    // The whole pipeline (allocator, pool size, SLO report) follows the
    // explicit hardware target.
    assert_eq!(
        run("serve-sim --models alexnet --target edge4 --requests 24 \
             --rate 100 --seed 5"),
        0);
}

#[test]
fn tune_batch_flag_happy_and_error_paths() {
    // Joint (MP, batch) co-optimization through every entry point.
    assert_eq!(run("tune alexnet --batch 1,2,4"), 0);
    assert_eq!(run("tune alexnet --tuner oracle --batch 1,8"), 0);
    assert_eq!(run("tune alexnet --compare --batch 1,4 --iterations 100"), 0);
    // Malformed or invalid candidate sets are clean errors.
    assert_eq!(run("tune alexnet --batch abc"), 1);
    assert_eq!(run("tune alexnet --batch 1,x"), 1);
    assert_eq!(run("tune alexnet --batch 0"), 1);
}

#[test]
fn serve_sim_batch_policy_happy_paths() {
    assert_eq!(
        run("serve-sim --models alexnet,mini_cnn --policy batch --requests 48 \
             --rate 500 --slo-ms 200 --seed 3"),
        0);
    assert_eq!(
        run("serve-sim --models alexnet --policy batch --max-batch 4 \
             --batch-wait-ms 1.5 --requests 32 --rate 400"),
        0);
    // Batch knobs on a non-batch policy are a note, not an error.
    assert_eq!(
        run("serve-sim --models alexnet --policy fifo --max-batch 4 \
             --requests 16 --rate 300"),
        0);
}

#[test]
fn serve_sim_batch_policy_rejects_bad_knobs() {
    assert_eq!(run("serve-sim --models alexnet --policy batch --max-batch 0"), 1);
    assert_eq!(run("serve-sim --models alexnet --policy batch --max-batch abc"), 1);
    assert_eq!(
        run("serve-sim --models alexnet --policy batch --batch-wait-ms -1"), 1);
    assert_eq!(
        run("serve-sim --models alexnet --policy batch --batch-wait-ms abc"), 1);
}

#[test]
fn perf_smoke_emits_json_and_compares_against_baseline() {
    let dir = std::env::temp_dir().join("dlfusion_cli_perf_smoke");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let out = dir.join("BENCH_ci.json");
    let baseline = dir.join("baseline.json");
    // --threads 1 keeps the test off the machine-dependent speedup floor
    // (it only arms at >= 4 threads on a >= 4-core box).
    // No baseline yet: still a success (bootstrap), and the JSON lands.
    assert_eq!(
        run(&format!("perf-smoke --threads 1 --out {} --baseline {}",
                     out.display(), baseline.display())),
        0);
    let text = std::fs::read_to_string(&out).unwrap();
    let doc = dlfusion::util::json::Json::parse(&text).unwrap();
    let metrics = doc.get("metrics").as_obj().unwrap();
    for key in ["resnet50_algorithm1_ms", "resnet50_oracle_ms",
                "vgg19_algorithm1_ms", "vgg19_oracle_ms",
                "serving_fifo_throughput_rps", "serving_fifo_goodput_rps",
                "batching_fifo_goodput_rps", "batching_batch_goodput_rps",
                "mlu100_resnet18_algorithm1_ms", "mlu100_resnet18_oracle_ms",
                "edge4_resnet18_algorithm1_ms", "edge4_resnet18_oracle_ms",
                "learned_resnet18_mape", "active_evals_saved_ratio"] {
        let v = metrics.get(key).and_then(|m| m.as_f64());
        assert!(v.is_some_and(|v| v.is_finite() && v > 0.0), "metric {key}: {v:?}");
    }
    // The wall-clock section rides alongside, under its own key.
    let wall = doc.get("wall_metrics").as_obj().unwrap();
    for key in ["tuning_throughput_evals_per_s", "parallel_speedup_x",
                "serve_events_per_s"] {
        let v = wall.get(key).and_then(|m| m.as_f64());
        assert!(v.is_some_and(|v| v.is_finite() && v > 0.0), "wall {key}: {v:?}");
    }
    // Record the baseline, re-run: the self-comparison is exact-gated and
    // must pass, and the simulated metrics (though not the wall-clock
    // section) are run-to-run identical.
    assert_eq!(
        run(&format!("perf-smoke --threads 1 --out {} --baseline {} \
                      --write-baseline",
                     out.display(), baseline.display())),
        0);
    assert_eq!(
        run(&format!("perf-smoke --threads 1 --out {} --baseline {}",
                     out.display(), baseline.display())),
        0);
    let again = std::fs::read_to_string(&out).unwrap();
    let doc2 = dlfusion::util::json::Json::parse(&again).unwrap();
    assert_eq!(doc.get("metrics"), doc2.get("metrics"),
               "perf-smoke simulated metrics must be run-to-run identical");
}

#[test]
fn serve_sim_rejects_bad_flags() {
    assert_eq!(run("serve-sim --models nope_net"), 1);
    assert_eq!(run("serve-sim --models alexnet --policy lifo"), 1);
    assert_eq!(run("serve-sim --models alexnet --rate 0"), 1);
    assert_eq!(run("serve-sim --models alexnet --rate -5"), 1);
    assert_eq!(run("serve-sim --models alexnet --rate abc"), 1);
    assert_eq!(run("serve-sim --models alexnet --arrivals sometimes"), 1);
    assert_eq!(run("serve-sim --models alexnet --slo-ms 0"), 1);
    assert_eq!(run("serve-sim --models alexnet --allocator psychic"), 1);
    assert_eq!(run("serve-sim --models alexnet --arrivals closed --concurrency 0"), 1);
}

#[test]
fn serve_sim_accepts_dag_and_file_workloads() {
    let dir = std::env::temp_dir().join("dlfusion_cli_serve_dag");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    // DAG zoo names serve through their linearization, and mix freely with
    // linear zoo models (the serve-sim workload-loading fix).
    assert_eq!(run("serve-sim --models resnet18-dag --requests 16 --rate 200"), 0);
    assert_eq!(
        run("serve-sim --models alexnet,resnet18-dag --requests 16 --rate 300"),
        0);
    // .dlm documents serve too: via --model-file and inline in --models.
    let v2 = dir.join("r18.dlm");
    assert_eq!(run(&format!("model export resnet18-dag --out {}", v2.display())), 0);
    assert_eq!(
        run(&format!("serve-sim --model-file {} --requests 16 --rate 200",
                     v2.display())),
        0);
    assert_eq!(
        run(&format!("serve-sim --models alexnet,{} --requests 16 --rate 300",
                     v2.display())),
        0);
    // Duplicate names would alias queues, lanes, and plan-cache keys.
    assert_eq!(run("serve-sim --models alexnet,alexnet"), 1);
    assert_eq!(run("serve-sim --models alexnet, --requests 8"), 1);
    assert_eq!(run("serve-sim --model-file /no/such/mix.dlm"), 1);
}

#[test]
fn serve_fleet_happy_paths() {
    // A one-chip fleet is the serve-sim degenerate case.
    assert_eq!(
        run("serve-fleet --fleet mlu100 --models alexnet --requests 32 \
             --rate 300 --seed 5"),
        0);
    // Heterogeneous fleet, SLO accounting, explicit routing.
    assert_eq!(
        run("serve-fleet --fleet mlu100,edge4x2 --models alexnet,mini_cnn \
             --requests 48 --rate 500 --route least-loaded --slo-ms 50"),
        0);
    assert_eq!(
        run("serve-fleet --fleet edge4x2 --models mini_cnn --requests 24 \
             --rate 200 --route rr"),
        0);
    assert_eq!(
        run("serve-fleet --fleet mlu100x2 --models alexnet,mini_cnn \
             --requests 32 --rate 400 --route sharded --no-events"),
        0);
    // Admission control and dynamic batching ride along.
    assert_eq!(
        run("serve-fleet --fleet edge4x2 --models mini_cnn --requests 32 \
             --rate 600 --queue-cap 2"),
        0);
    assert_eq!(
        run("serve-fleet --fleet mlu100 --models alexnet --policy batch \
             --max-batch 4 --requests 32 --rate 400 --arrivals bursty"),
        0);
}

#[test]
fn serve_fleet_rejects_bad_flags() {
    assert_eq!(run("serve-fleet --fleet tpu9000x2"), 1);
    assert_eq!(run("serve-fleet --fleet mlu100x0"), 1);
    assert_eq!(run("serve-fleet --fleet"), 1);
    assert_eq!(run("serve-fleet --route lifo"), 1);
    assert_eq!(run("serve-fleet --queue-cap 0"), 1);
    assert_eq!(run("serve-fleet --queue-cap abc"), 1);
    assert_eq!(run("serve-fleet --models nope_net"), 1);
    assert_eq!(run("serve-fleet --models alexnet,alexnet"), 1);
    assert_eq!(run("serve-fleet --rate 0"), 1);
    assert_eq!(run("serve-fleet --slo-ms -3"), 1);
    assert_eq!(run("serve-fleet --allocator psychic"), 1);
    assert_eq!(run("serve-fleet --policy batch --max-batch 0"), 1);
    // Fleets are open-loop only: no single concurrency gate exists.
    assert_eq!(run("serve-fleet --arrivals closed"), 1);
    assert_eq!(run("serve-fleet --arrivals sometimes"), 1);
    // The fleet trace replays recorded events; --no-events removes them.
    assert_eq!(
        run("serve-fleet --requests 8 --no-events --trace-out /tmp/x.json"), 1);
}

#[test]
fn serve_fleet_observability_exports() {
    let dir = std::env::temp_dir().join("dlfusion_cli_obs_fleet");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let metrics = dir.join("metrics.json");
    let trace = dir.join("trace.json");
    assert_eq!(
        run(&format!("serve-fleet --fleet mlu100,edge4 --models mini_cnn \
                      --requests 24 --rate 300 --slo-ms 50 --metrics-out {} \
                      --trace-out {}",
                     metrics.display(), trace.display())),
        0);
    // Fleet metrics are all event-clock state: the merged SLO gauges plus
    // per-chip gauges land in the deterministic section, wall stays empty.
    let doc = dlfusion::util::json::Json::parse(
        &std::fs::read_to_string(&metrics).unwrap()).unwrap();
    assert!(doc.get("deterministic").get("serving.throughput_rps")
            .as_f64().is_some_and(|v| v > 0.0));
    for chip in ["mlu100-0", "edge4-0"] {
        assert!(doc.get("deterministic")
                .get(&format!("serving.chip.{chip}.requests"))
                .as_f64().is_some(), "missing per-chip gauges for {chip}");
    }
    assert!(doc.get("wall").as_obj().unwrap().is_empty());
    let tdoc = dlfusion::util::json::Json::parse(
        &std::fs::read_to_string(&trace).unwrap()).unwrap();
    assert!(!tdoc.get("traceEvents").as_arr().unwrap().is_empty());
    assert_eq!(run(&format!("report {}", metrics.display())), 0);
}

#[test]
fn unknown_command_fails() {
    assert_eq!(run("frobnicate"), 1);
}

#[test]
fn tune_observability_exports_and_report_roundtrip() {
    let dir = std::env::temp_dir().join("dlfusion_cli_obs_tune");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let metrics = dir.join("metrics.json");
    let prom = dir.join("metrics.prom");
    let trace = dir.join("trace.json");
    assert_eq!(
        run(&format!("tune alexnet --tuner oracle --metrics-out {} --trace-out {}",
                     metrics.display(), trace.display())),
        0);
    // The snapshot splits domains: search-space counters are deterministic,
    // timers live under "wall".
    let doc = dlfusion::util::json::Json::parse(
        &std::fs::read_to_string(&metrics).unwrap()).unwrap();
    assert!(doc.get("deterministic").get("tuner.evaluations")
            .as_f64().is_some_and(|v| v > 0.0));
    assert!(doc.get("deterministic").get("cost.cache.misses")
            .as_f64().is_some_and(|v| v > 0.0));
    assert!(doc.get("wall").get("tuner.wall_us")
            .as_f64().is_some_and(|v| v > 0.0));
    // The trace is a chrome trace-event document with at least one span.
    let tdoc = dlfusion::util::json::Json::parse(
        &std::fs::read_to_string(&trace).unwrap()).unwrap();
    assert!(!tdoc.get("traceEvents").as_arr().unwrap().is_empty());
    // A .prom suffix switches to Prometheus exposition text.
    assert_eq!(run(&format!("tune alexnet --metrics-out {}", prom.display())), 0);
    let text = std::fs::read_to_string(&prom).unwrap();
    assert!(text.contains("dlfusion_tuner_evaluations"));
    assert!(text.contains("domain=\"wall\""));
    // `report` renders the JSON snapshot as a table or as Prometheus text.
    assert_eq!(run(&format!("report {}", metrics.display())), 0);
    assert_eq!(run(&format!("report {} --prom", metrics.display())), 0);
    // perf-smoke documents ride the same parser (metrics/wall_metrics keys).
    let smoke = dir.join("smoke.json");
    std::fs::write(&smoke,
                   r#"{"schema": 2, "metrics": {"a_ms": 1.5}, "wall_metrics": {}}"#)
        .unwrap();
    assert_eq!(run(&format!("report {}", smoke.display())), 0);
}

#[test]
fn serve_sim_observability_exports() {
    let dir = std::env::temp_dir().join("dlfusion_cli_obs_serve");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let metrics = dir.join("metrics.json");
    let trace = dir.join("trace.json");
    assert_eq!(
        run(&format!("serve-sim --models alexnet --requests 32 --rate 300 \
                      --slo-ms 50 --metrics-out {} --trace-out {}",
                     metrics.display(), trace.display())),
        0);
    // Everything serving reports is event-clock state: the deterministic
    // section carries the SLO metrics, the wall section stays empty.
    let doc = dlfusion::util::json::Json::parse(
        &std::fs::read_to_string(&metrics).unwrap()).unwrap();
    assert!(doc.get("deterministic").get("serving.throughput_rps")
            .as_f64().is_some_and(|v| v > 0.0));
    assert!(doc.get("wall").as_obj().unwrap().is_empty());
    let tdoc = dlfusion::util::json::Json::parse(
        &std::fs::read_to_string(&trace).unwrap()).unwrap();
    assert!(!tdoc.get("traceEvents").as_arr().unwrap().is_empty());
    assert_eq!(run(&format!("report {}", metrics.display())), 0);
}

#[test]
fn observability_flag_error_paths() {
    let dir = std::env::temp_dir().join("dlfusion_cli_obs_err");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    // Bare flags expect a value.
    assert_eq!(run("tune alexnet --metrics-out"), 1);
    assert_eq!(run("tune alexnet --trace-out"), 1);
    assert_eq!(run("serve-sim --models alexnet --requests 8 --metrics-out"), 1);
    // Unwritable destination (parent is a regular file) is a clean error.
    let blocker = dir.join("not_a_dir");
    std::fs::write(&blocker, "x").unwrap();
    let unwritable = blocker.join("x.json");
    assert_eq!(run(&format!("tune alexnet --metrics-out {}",
                            unwritable.display())), 1);
    assert_eq!(run(&format!("serve-sim --models alexnet --requests 8 \
                             --trace-out {}", unwritable.display())), 1);
    // The exports describe one backend's run, not a comparison.
    assert_eq!(run("tune alexnet --compare --metrics-out /tmp/x.json"), 1);
    assert_eq!(run("tune alexnet --compare-targets --trace-out /tmp/x.json"), 1);
    // The sim trace replays the event log; --no-events removes it.
    assert_eq!(run("serve-sim --models alexnet --requests 8 --no-events \
                    --trace-out /tmp/x.json"), 1);
    // report: missing operand, missing file, malformed JSON, no sections.
    assert_eq!(run("report"), 1);
    assert_eq!(run("report /no/such/snapshot.json"), 1);
    let bad = dir.join("bad.json");
    std::fs::write(&bad, "{nope").unwrap();
    assert_eq!(run(&format!("report {}", bad.display())), 1);
    let empty = dir.join("empty.json");
    std::fs::write(&empty, r#"{"schema": 2}"#).unwrap();
    assert_eq!(run(&format!("report {}", empty.display())), 1);
}

#[test]
fn codegen_writes_files() {
    let out = std::env::temp_dir().join("dlfusion_cli_codegen");
    let _ = std::fs::remove_dir_all(&out);
    let code = run(&format!("codegen mini_cnn --out {}", out.display()));
    assert_eq!(code, 0);
    assert!(out.join("mini_cnn_inference.cpp").exists());
    assert!(out.join("cnml_compat.h").exists());
}

#[test]
fn model_subcommands_happy_paths() {
    let dir = std::env::temp_dir().join("dlfusion_cli_model_cmd");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    // Export prints to stdout, or writes --out; v1 for chains, v2 for dags.
    assert_eq!(run("model export mini_cnn"), 0);
    assert_eq!(run("model export resnet18-dag"), 0);
    let v1 = dir.join("mini.dlm");
    let v2 = dir.join("r18.dlm");
    assert_eq!(run(&format!("model export mini_cnn --out {}", v1.display())), 0);
    assert_eq!(run(&format!("model export resnet18-dag --out {}", v2.display())), 0);
    // Import validates both on-disk versions.
    assert_eq!(run(&format!("model import {}", v1.display())), 0);
    assert_eq!(run(&format!("model import {}", v2.display())), 0);
    // Show renders zoo names, dag names, and files.
    assert_eq!(run("model show mini_cnn"), 0);
    assert_eq!(run("model show resnet18-dag"), 0);
    assert_eq!(run(&format!("model show {}", v2.display())), 0);
    // The acceptance pipeline: an exported v2 document imports and tunes.
    assert_eq!(run(&format!("tune --model-file {}", v2.display())), 0);
    assert_eq!(run(&format!("tune --model-file {} --tuner oracle", v1.display())), 0);
    // A .dlm positional resolves v2 too (suffix routing).
    assert_eq!(run(&format!("tune {}", v2.display())), 0);
}

#[test]
fn model_subcommands_error_paths() {
    let dir = std::env::temp_dir().join("dlfusion_cli_model_err");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    // Missing / unknown verbs and operands.
    assert_eq!(run("model"), 1);
    assert_eq!(run("model frobnicate"), 1);
    assert_eq!(run("model import"), 1);
    assert_eq!(run("model export nope_net"), 1);
    assert_eq!(run("model show nope_net"), 1);
    // Missing file.
    assert_eq!(run("model import /no/such/file.dlm"), 1);
    assert_eq!(run("tune --model-file /no/such/file.dlm"), 1);
    // Malformed JSON.
    let bad = dir.join("bad.dlm");
    std::fs::write(&bad, "{nope").unwrap();
    assert_eq!(run(&format!("model import {}", bad.display())), 1);
    assert_eq!(run(&format!("model show {}", bad.display())), 1);
    // v2 features in a v1 document: per-layer dataflow is rejected, not
    // silently ignored.
    let mixed = dir.join("mixed.dlm");
    std::fs::write(
        &mixed,
        r#"{"name": "t", "input": [8, 8, 3], "layers": [
            {"name": "c1", "op": "conv", "c_in": 3, "c_out": 8, "h_in": 8,
             "w_in": 8, "k": 3, "stride": 1, "pad": 1, "groups": 1},
            {"name": "r1", "op": "relu", "shape": [8, 8, 8], "inputs": ["c1"]}
        ]}"#,
    )
    .unwrap();
    assert_eq!(run(&format!("model import {}", mixed.display())), 1);
    // Unsupported version number.
    let v9 = dir.join("v9.dlm");
    std::fs::write(&v9, r#"{"version": 9, "name": "t"}"#).unwrap();
    assert_eq!(run(&format!("model import {}", v9.display())), 1);
}

#[test]
fn tune_handles_branching_dag_workloads() {
    // The DAG zoo variants tune end-to-end, fusion confined to legal cuts.
    assert_eq!(run("tune resnet18-dag"), 0);
    assert_eq!(run("tune resnet50-dag"), 0);
    assert_eq!(run("tune resnet18-dag --tuner oracle"), 0);
    assert_eq!(run("tune resnet18-dag --tuner anneal --iterations 100"), 0);
    assert_eq!(run("tune resnet18-dag --compare --iterations 100"), 0);
    assert_eq!(run("tune resnet18-dag --compare-targets"), 0);
    // Table III strategies are defined over linear chains only.
    assert_eq!(run("tune resnet18-dag --tuner strategy3"), 1);
}

#[test]
fn linear_only_commands_reject_branching_dags() {
    assert_eq!(run("optimize resnet18-dag"), 1);
    assert_eq!(run("simulate resnet18-dag"), 1);
    assert_eq!(run("search resnet18-dag"), 1);
    assert_eq!(run("trace resnet18-dag"), 1);
    assert_eq!(run("codegen resnet18-dag"), 1);
}

#[test]
fn optimize_dlm_file() {
    let dir = std::env::temp_dir().join("dlfusion_cli_dlm");
    std::fs::create_dir_all(&dir).unwrap();
    let model = dlfusion::zoo::mini_cnn();
    let path = dir.join("mini.dlm");
    std::fs::write(&path, dlfusion::graph::format::to_dlm(&model)).unwrap();
    assert_eq!(run(&format!("optimize {}", path.display())), 0);
    // Corrupt file -> error.
    std::fs::write(dir.join("bad.dlm"), "{nope").unwrap();
    assert_eq!(run(&format!("optimize {}", dir.join("bad.dlm").display())), 1);
}

#[test]
fn learn_fit_eval_transfer_happy_paths() {
    let dir = std::env::temp_dir().join("dlfusion_cli_learn");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let model_file = dir.join("fitted.json");
    let metrics = dir.join("metrics.json");
    // Fit prints the report and saves the versioned model file.
    assert_eq!(
        run(&format!("learn fit resnet18 --out {} --metrics-out {}",
                     model_file.display(), metrics.display())),
        0);
    let doc = dlfusion::util::json::Json::parse(
        &std::fs::read_to_string(&model_file).unwrap()).unwrap();
    assert_eq!(doc.get("format").as_str(),
               Some("dlfusion-learned-cost-model"));
    let snap = dlfusion::util::json::Json::parse(
        &std::fs::read_to_string(&metrics).unwrap()).unwrap();
    assert!(snap.get("deterministic").get("learn.fit.r2_train")
            .as_f64().is_some_and(|v| v > 0.5));
    // Eval scores the saved file, on the fit target and across targets.
    assert_eq!(run(&format!("learn eval resnet18 {}", model_file.display())), 0);
    assert_eq!(run(&format!("learn eval resnet18 {} --target edge4",
                            model_file.display())), 0);
    // PCA-reduced fits and dag workloads ride the same surface.
    assert_eq!(run("learn fit alexnet --pca 6 --holdout 0.2 --seed 7"), 0);
    assert_eq!(run("learn fit resnet18-dag"), 0);
    // Transfer sweeps the registry (default workload when none is named).
    assert_eq!(run("learn transfer alexnet"), 0);
}

#[test]
fn learn_error_paths_are_clean() {
    // Missing/unknown verbs and workloads are usage errors, not panics.
    assert_eq!(run("learn"), 1);
    assert_eq!(run("learn frobnicate"), 1);
    assert_eq!(run("learn fit"), 1);
    assert_eq!(run("learn fit nope_net"), 1);
    assert_eq!(run("learn fit resnet18 --target tpu9"), 1);
    assert_eq!(run("learn fit resnet18 --pca 99"), 1);
    assert_eq!(run("learn fit resnet18 --holdout 1.5"), 1);
    // Eval needs both the workload and a readable, well-formed model file.
    assert_eq!(run("learn eval resnet18"), 1);
    assert_eq!(run("learn eval resnet18 /nonexistent/model.json"), 1);
    let dir = std::env::temp_dir().join("dlfusion_cli_learn_bad");
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("bad.json");
    std::fs::write(&bad, "{ not json").unwrap();
    assert_eq!(run(&format!("learn eval resnet18 {}", bad.display())), 1);
    let wrong = dir.join("wrong.json");
    std::fs::write(&wrong, r#"{"format": "something-else"}"#).unwrap();
    assert_eq!(run(&format!("learn eval resnet18 {}", wrong.display())), 1);
    assert_eq!(run("learn transfer nope_net"), 1);
}

#[test]
fn tune_learned_backend_happy_paths() {
    // The learned backend rides the whole tune surface: single runs,
    // comparisons, cross-target sweeps, dag constraints, batch sets.
    assert_eq!(run("tune resnet18 --tuner learned"), 0);
    assert_eq!(run("tune alexnet --tuner learned --target edge4"), 0);
    assert_eq!(run("tune alexnet --compare --tuner learned"), 0);
    assert_eq!(run("tune alexnet --tuner learned --compare-targets"), 0);
    assert_eq!(run("tune resnet18-dag --tuner learned"), 0);
    assert_eq!(run("tune alexnet --tuner learned --batch 1,4"), 0);
    // `active` is a registered alias of the same backend.
    assert_eq!(run("tune alexnet --tuner active"), 0);
    // Unknown tuner names still fail cleanly.
    assert_eq!(run("tune alexnet --tuner learnt"), 1);
}
