//! Integration: the cost-evaluation engine's exactness contract
//! (rust/docs/DESIGN.md §7) and the regression pin for the simulator's
//! batched fast path.
//!
//! The property tests here are the crate's guarantee that routing every
//! consumer through `CostEngine` changed *nothing* numerically: the scalar
//! engine path is bit-identical to `Simulator::{layer,block}_latency_ms` /
//! `run_schedule`, the MP-sweep path is bit-identical to
//! `Simulator::block_latency_ms_multi`, the sweep path agrees with the
//! scalar reference to 1e-12 per MP (the seed relationship, kept as the pin
//! now that both are fact-table walks), and the batch-keyed cache pins
//! `batch = 1` to the pre-batch bits.
#![allow(deprecated)] // exercises the legacy shims alongside the tuner API

use dlfusion::accel::{Simulator, Target};
use dlfusion::cost::CostEngine;
use dlfusion::graph::Model;
use dlfusion::optimizer::{Block, Schedule};
use dlfusion::testutil::prop::{forall, Gen};
use dlfusion::util::XorShiftRng;
use dlfusion::zoo;

fn models() -> Vec<Model> {
    vec![zoo::resnet18(), zoo::resnet50(), zoo::vgg19(), zoo::alexnet(),
         zoo::mobilenet_v2(), zoo::mini_cnn()]
}

/// Random (model, block range, MP set) — the satellite's randomized
/// blocks/MP-set generator.
fn block_case(models: &[Model])
              -> Gen<'_, (usize, usize, usize, Vec<usize>)> {
    Gen::new(move |rng: &mut XorShiftRng| {
        let mi = rng.gen_usize(0, models.len() - 1);
        let n = models[mi].num_layers();
        let start = rng.gen_usize(0, n - 1);
        let end = rng.gen_usize(start + 1, n);
        let count = rng.gen_usize(1, 6);
        let mps: Vec<usize> = (0..count).map(|_| rng.gen_usize(1, 32)).collect();
        (mi, start, end, mps)
    })
}

#[test]
fn prop_multi_matches_per_mp_scalar() {
    // The seed pin: `block_latency_ms_multi` ≡ per-MP `block_latency_ms`
    // over randomized blocks and MP sets. `block_latency_ms_multi` is now a
    // `ModelFacts` walk, so this transitively pins the engine's fast path
    // against the untouched scalar reference.
    let sim = Simulator::new(Target::mlu100());
    let models = models();
    let g = block_case(&models);
    forall(200, &g, |(mi, start, end, mps)| {
        let m = &models[*mi];
        let layers = &m.layers[*start..*end];
        let multi = sim.block_latency_ms_multi(layers, mps);
        for (&mp, &fast) in mps.iter().zip(&multi) {
            let slow = sim.block_latency_ms(layers, mp);
            if (fast - slow).abs() > 1e-12 {
                return Err(format!(
                    "{} [{start}..{end}] mp={mp}: batched {fast} vs scalar {slow}",
                    m.name
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_engine_paths_bit_identical_to_simulator() {
    let sim = Simulator::new(Target::mlu100());
    let models = models();
    let g = block_case(&models);
    forall(120, &g, |(mi, start, end, mps)| {
        let m = &models[*mi];
        let layers = &m.layers[*start..*end];
        let mut engine = CostEngine::new(&sim, m);
        for &mp in mps {
            let got = engine.block_latency(*start, *end, mp);
            let want = sim.block_latency_ms(layers, mp);
            if got != want {
                return Err(format!(
                    "scalar {} [{start}..{end}] mp={mp}: {got} != {want}", m.name
                ));
            }
            // Cached re-query returns the same bits.
            if engine.block_latency(*start, *end, mp) != got {
                return Err("cache returned different bits".into());
            }
        }
        let got = engine.block_latency_sweep(*start, *end, mps);
        let want = sim.block_latency_ms_multi(layers, mps);
        if got != want {
            return Err(format!(
                "batched {} [{start}..{end}]: {got:?} != {want:?}", m.name
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_batch_one_engine_bit_identical_to_prebatch_scalar_path() {
    // The PR 4 pin: keying the cache by (start, end, mp, batch) with the
    // default batch 1 changed *nothing* — every engine query still returns
    // exactly the bits of the untouched Simulator scalar/multi paths, via
    // the explicit-batch accessor, the active-batch accessor, and after
    // visiting other batches.
    let sim = Simulator::new(Target::mlu100());
    let models = models();
    let g = block_case(&models);
    forall(120, &g, |(mi, start, end, mps)| {
        let m = &models[*mi];
        let layers = &m.layers[*start..*end];
        let mut engine = CostEngine::new(&sim, m);
        for &mp in mps {
            let want = sim.block_latency_ms(layers, mp);
            if engine.block_cost_at(*start, *end, mp, 1).latency_ms != want {
                return Err(format!(
                    "explicit batch-1 {} [{start}..{end}] mp={mp}", m.name));
            }
            // Evaluate a larger batch in between: the batch-keyed cache
            // must not perturb the batch-1 entry.
            let b4 = engine.block_cost_at(*start, *end, mp, 4).latency_ms;
            if !(b4 >= want) {
                return Err(format!(
                    "batch-4 cheaper than batch-1 {} [{start}..{end}] mp={mp}",
                    m.name));
            }
            if b4 >= 4.0 * want {
                return Err(format!(
                    "batch-4 not sub-linear {} [{start}..{end}] mp={mp}", m.name));
            }
            if engine.block_latency(*start, *end, mp) != want {
                return Err(format!(
                    "active batch-1 {} [{start}..{end}] mp={mp}", m.name));
            }
        }
        let multi = engine.block_latency_sweep(*start, *end, mps);
        if multi != sim.block_latency_ms_multi(layers, mps) {
            return Err(format!("multi path {} [{start}..{end}]", m.name));
        }
        Ok(())
    });
}

fn random_schedule(rng: &mut XorShiftRng, n: usize, max_mp: usize) -> Schedule {
    let mut blocks = Vec::new();
    let mut start = 0usize;
    while start < n {
        let len = rng.gen_usize(1, (n - start).min(6));
        let mp = (1usize << rng.gen_usize(0, 5)).min(max_mp);
        blocks.push(Block { start, end: start + len, mp });
        start += len;
    }
    Schedule::new(blocks)
}

#[test]
fn prop_engine_run_schedule_bit_identical() {
    let sim = Simulator::new(Target::mlu100());
    let models = models();
    let g = Gen::new(|rng: &mut XorShiftRng| {
        let mi = rng.gen_usize(0, models.len() - 1);
        let seed = rng.next_u64();
        (mi, seed)
    });
    forall(60, &g, |&(mi, seed)| {
        let m = &models[mi];
        let mut rng = XorShiftRng::new(seed);
        let sched = random_schedule(&mut rng, m.num_layers(), sim.spec.num_cores);
        let mut engine = CostEngine::new(&sim, m);
        let got = engine.run_schedule(&sched);
        let want = sim.run_schedule(m, &sched);
        if got != want {
            return Err(format!("{}: engine report diverged for {}",
                               m.name, sched.summary()));
        }
        Ok(())
    });
}

#[test]
fn prop_delta_cost_matches_fresh_evaluation() {
    let sim = Simulator::new(Target::mlu100());
    let m = zoo::resnet18();
    let g = Gen::new(|rng: &mut XorShiftRng| rng.next_u64());
    forall(40, &g, |&seed| {
        let mut rng = XorShiftRng::new(seed);
        let sched = random_schedule(&mut rng, m.num_layers(), sim.spec.num_cores);
        let mut engine = CostEngine::new(&sim, &m);
        let base = engine.schedule_cost(&sched);
        if base != sim.run_schedule(&m, &sched).total_ms {
            return Err("schedule_cost != run_schedule.total_ms".into());
        }
        // Local move: change one block's MP, evaluate incrementally.
        let bi = rng.gen_usize(0, sched.blocks.len() - 1);
        let mut moved = sched.clone();
        moved.blocks[bi] = Block {
            mp: if moved.blocks[bi].mp == 1 { 2 } else { 1 },
            ..moved.blocks[bi]
        };
        let incremental = engine.delta_cost(&moved, &[bi]);
        let fresh = sim.run_schedule(&m, &moved).total_ms;
        if incremental != fresh {
            return Err(format!("delta {incremental} != fresh {fresh}"));
        }
        Ok(())
    });
}

#[test]
fn engine_and_oracle_agree_with_seed_strategy_seven() {
    // End-to-end: strategy 7 through the public API must equal the report
    // the untouched simulator produces for the oracle's schedule.
    let sim = Simulator::new(Target::mlu100());
    let m = zoo::resnet18();
    let (sched, rep) = dlfusion::optimizer::run_strategy(
        &sim, &m, dlfusion::optimizer::Strategy::BruteForce);
    assert_eq!(rep, sim.run_schedule(&m, &sched));
    let (oracle, stats) = dlfusion::search::oracle_schedule(&sim, &m);
    assert_eq!(sched, oracle);
    assert_eq!(stats.cache_hits + stats.cache_misses, stats.evaluations);
}
