//! Integration: the multi-chip fleet layer (ISSUE PR 9 acceptance) — the
//! one-chip-fleet parity pin against the single-pool simulation, fleet-run
//! determinism, routing-policy separation under overload, admission-control
//! accounting, and the plan cache's tune-each-key-exactly-once guarantee.

use dlfusion::accel::{Simulator, Target};
use dlfusion::obs::MetricsRegistry;
use dlfusion::serving::{self, fleet_trace, plan_fleet, AllocationRequest,
                        ArrivalProcess, ClusterConfig, DispatchPolicy, Fleet,
                        FleetReport, FleetRun, ModelMix, PlanCache, Request,
                        RoutePolicy, RouterConfig, SimulationRun, SloReport};
use dlfusion::zoo;

const POLICIES: [RoutePolicy; 3] =
    [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded,
     RoutePolicy::ModelSharded];

/// The tentpole's backward-compatibility pin: a one-chip fleet with no
/// queue cap reproduces the single-pool `serve-sim` path bit for bit —
/// same completions and events under every routing policy, same rendered
/// SLO report, same metrics snapshot.
#[test]
fn one_chip_fleet_reproduces_the_single_pool_simulation() {
    let sim = Simulator::new(Target::mlu100());
    let mix = ModelMix::uniform(vec![zoo::resnet18(), zoo::alexnet()]);
    let trace = serving::generate_trace(
        &mix, ArrivalProcess::OpenPoisson { rate_rps: 400.0 }, 200, 7);

    // The single-pool path, exactly as `serve-sim` runs it.
    let plan =
        AllocationRequest::new(&sim, &mix).slo_ms(Some(50.0)).plan().unwrap();
    let cfg = ClusterConfig { num_cores: sim.spec.num_cores,
                              policy: DispatchPolicy::Fifo };
    let single = SimulationRun::new(&cfg, &plan.services(true))
        .trace(&trace)
        .run()
        .unwrap();

    // The same workload as a one-chip fleet: every policy degenerates to
    // pass-through, so the merged result is the chip's result verbatim.
    let fleet = Fleet::parse("mlu100").unwrap();
    let mut cache = PlanCache::new();
    let fplan =
        plan_fleet(&fleet, &mix, Some(50.0), 1, true, &mut cache).unwrap();
    for policy in POLICIES {
        let result = FleetRun::new(&fplan, RouterConfig::new(policy))
            .trace(&trace)
            .run()
            .unwrap();
        assert!(result.shed.is_empty(), "{}", policy.name());
        assert_eq!(result.merged(), single,
                   "one-chip fleet under {} must be bit-identical to the \
                    single pool", policy.name());
    }

    // The report surface pins too: rendered SLO table and exported
    // metrics are byte-identical (zero shed is invisible by design).
    let result = FleetRun::new(&fplan, RouterConfig::new(RoutePolicy::LeastLoaded))
        .trace(&trace)
        .run()
        .unwrap();
    let report = FleetReport::from_run(&result, &fplan, Some(50.0));
    let single_report = SloReport::from_sim(&single, Some(50.0));
    assert_eq!(report.slo.render(), single_report.render());
    let mut reg_fleet = MetricsRegistry::new();
    report.slo.export_metrics(&mut reg_fleet);
    let mut reg_single = MetricsRegistry::new();
    single_report.export_metrics(&mut reg_single);
    assert_eq!(reg_fleet.snapshot().to_string(),
               reg_single.snapshot().to_string());
}

/// Same seed ⇒ identical per-chip results, shed log, rendered fleet
/// report, and Chrome trace export on a heterogeneous fleet; a different
/// seed diverges. Routing reads only simulated state, so no wall clock can
/// leak into a fleet run.
#[test]
fn same_seed_pins_the_fleet_run_and_its_exports() {
    let mix = ModelMix::uniform(vec![zoo::alexnet(), zoo::mini_cnn()]);
    let fleet = Fleet::parse("mlu100,edge4x2").unwrap();
    let mut cache = PlanCache::new();
    let plan = plan_fleet(&fleet, &mix, None, 1, true, &mut cache).unwrap();
    let router =
        RouterConfig::new(RoutePolicy::LeastLoaded).queue_cap(Some(16));
    let run = |seed: u64| {
        let trace = serving::generate_trace(
            &mix, ArrivalProcess::OpenPoisson { rate_rps: 600.0 }, 240, seed);
        let result =
            FleetRun::new(&plan, router).trace(&trace).run().unwrap();
        let report = FleetReport::from_run(&result, &plan, Some(50.0));
        let chrome = fleet_trace(&result, &plan, "parity").to_chrome_string();
        (result, report.render(), chrome)
    };
    let (r1, rep1, tr1) = run(42);
    let (r2, rep2, tr2) = run(42);
    assert_eq!(r1.per_chip, r2.per_chip);
    assert_eq!(r1.shed, r2.shed);
    assert_eq!(rep1, rep2);
    assert_eq!(tr1, tr2, "fleet trace export must be bit-identical");
    let (r3, _, _) = run(43);
    assert_ne!(r1.per_chip, r3.per_chip,
               "different seed must change the fleet run");
}

/// The routing acceptance criterion: on the overloaded vgg19+resnet18 mix
/// over a heterogeneous fleet, load-aware `least-loaded` routing achieves
/// strictly higher goodput than load-blind `round-robin`, which keeps
/// sending every other request to the narrow edge chips.
#[test]
fn least_loaded_beats_round_robin_goodput_under_overload() {
    let mix = ModelMix::uniform(vec![zoo::vgg19(), zoo::resnet18()]);
    let fleet = Fleet::parse("mlu100,edge4x2").unwrap();
    let mut cache = PlanCache::new();
    let plan = plan_fleet(&fleet, &mix, None, 1, true, &mut cache).unwrap();
    // An SLO generous to the slowest chip's invocation latency, so the
    // comparison is about sustained queueing, not one service time.
    let slo = 3.0 * plan
        .chips
        .iter()
        .flat_map(|cp| cp.services.iter())
        .map(|s| s.service_at(1))
        .fold(0.0, f64::max);
    let rate = 2.0 * plan.predicted_capacity_rps(true);
    let trace = serving::generate_trace(
        &mix, ArrivalProcess::OpenPoisson { rate_rps: rate }, 400, 11);
    let run = |policy| {
        let result = FleetRun::new(&plan, RouterConfig::new(policy))
            .trace(&trace)
            .run()
            .unwrap();
        FleetReport::from_run(&result, &plan, Some(slo))
    };
    let ll = run(RoutePolicy::LeastLoaded);
    let rr = run(RoutePolicy::RoundRobin);
    // No shedding: both policies complete the identical request set.
    assert_eq!(ll.slo.counters.get("requests"),
               rr.slo.counters.get("requests"));
    assert!(ll.slo.goodput_rps > rr.slo.goodput_rps,
            "least-loaded {} req/s goodput must strictly beat round-robin \
             {} req/s (SLO {slo:.1} ms, offered {rate:.0} req/s)",
            ll.slo.goodput_rps, rr.slo.goodput_rps);
}

/// The plan-cache acceptance criterion: across a fleet with repeated chip
/// kinds, each `(model, target, batch)` key is tuned exactly once — misses
/// count kinds x models, every further chip is a hit, and chips of the
/// same kind carry identical plans.
#[test]
fn plan_cache_tunes_each_key_exactly_once_across_the_fleet() {
    let mix = ModelMix::uniform(vec![zoo::alexnet(), zoo::mini_cnn()]);
    let fleet = Fleet::parse("mlu100x2,edge4x2").unwrap();
    let mut cache = PlanCache::new();
    let plan = plan_fleet(&fleet, &mix, None, 1, true, &mut cache).unwrap();
    let kinds = fleet.kinds().len() as u64;
    let models = mix.models.len() as u64;
    assert_eq!(plan.cache_stats.misses, kinds * models);
    assert_eq!(plan.cache_stats.hits,
               (fleet.len() as u64 - kinds) * models);
    assert!(plan.cache_stats.evals_saved > 0);
    assert_eq!(cache.len(), (kinds * models) as usize);
    // Same-kind chips share the tuned plan bit for bit.
    assert_eq!(plan.chips[0].plan, plan.chips[1].plan);
    assert_eq!(plan.chips[2].plan, plan.chips[3].plan);
    // Re-planning the same fleet is all hits, nothing re-tuned.
    let again = plan_fleet(&fleet, &mix, None, 1, true, &mut cache).unwrap();
    assert_eq!(again.cache_stats.misses, 0);
    assert_eq!(again.cache_stats.hits, fleet.len() as u64 * models);
    assert_eq!(again.cache_stats.evals_spent, 0);
    // The render carries the accounting line the CLI prints.
    assert!(plan.render(true).contains("plan cache:"), "{}", plan.render(true));
}

/// Admission control: with a queue cap under overload some requests shed,
/// every offered request is exactly one of completed or shed, and the
/// report/trace surfaces account for them.
#[test]
fn queue_cap_sheds_deterministically_and_accounts_every_request() {
    let mix = ModelMix::uniform(vec![zoo::alexnet()]);
    let fleet = Fleet::parse("edge4x2").unwrap();
    let mut cache = PlanCache::new();
    let plan = plan_fleet(&fleet, &mix, None, 1, true, &mut cache).unwrap();
    let rate = 4.0 * plan.predicted_capacity_rps(true);
    let trace = serving::generate_trace(
        &mix, ArrivalProcess::OpenPoisson { rate_rps: rate }, 200, 21);
    let router = RouterConfig::new(RoutePolicy::LeastLoaded).queue_cap(Some(2));
    let result = FleetRun::new(&plan, router).trace(&trace).run().unwrap();
    assert!(!result.shed.is_empty(), "4x overload with cap 2 must shed");
    assert_eq!(result.offered(), trace.len() as u64);
    assert_eq!(result.completed() + result.shed.len() as u64,
               result.offered());
    assert!(result.shed_rate() > 0.0 && result.shed_rate() < 1.0);
    // Determinism covers the shed log itself.
    let again = FleetRun::new(&plan, router).trace(&trace).run().unwrap();
    assert_eq!(result.shed, again.shed);
    // Report: the shed row and rate appear, and completed + shed adds up.
    let report = FleetReport::from_run(&result, &plan, None);
    assert_eq!(report.slo.shed, result.shed.len() as u64);
    assert_eq!(report.slo.counters.get("requests") + report.slo.shed,
               trace.len() as u64);
    assert!(report.render().contains("requests shed"), "{}", report.render());
    let mut reg = MetricsRegistry::new();
    report.export_metrics(&mut reg);
    assert!(reg.gauge("serving.shed_rate").is_some());
    // Trace: shed instants and the cumulative shed counter are exported.
    let chrome = fleet_trace(&result, &plan, "shed").to_chrome_string();
    assert!(chrome.contains("shed requests"), "missing shed counter track");
}

/// `model-sharded` routing is binding: every completion lands on the chip
/// the fleet plan pinned its model to.
#[test]
fn model_sharded_routing_pins_models_to_their_chips() {
    let mix = ModelMix::uniform(vec![zoo::vgg19(), zoo::mini_cnn()]);
    let fleet = Fleet::parse("mlu100,edge4").unwrap();
    let mut cache = PlanCache::new();
    let plan = plan_fleet(&fleet, &mix, None, 1, true, &mut cache).unwrap();
    let trace = serving::generate_trace(
        &mix, ArrivalProcess::OpenPoisson { rate_rps: 200.0 }, 120, 3);
    let result =
        FleetRun::new(&plan, RouterConfig::new(RoutePolicy::ModelSharded))
            .trace(&trace)
            .run()
            .unwrap();
    assert_eq!(result.completed(), trace.len() as u64);
    for (c, r) in result.per_chip.iter().enumerate() {
        for done in &r.completed {
            assert_eq!(plan.shard_of[done.model], c,
                       "model {} completed on chip {c} but is sharded to \
                        chip {}", done.model, plan.shard_of[done.model]);
        }
    }
    // The placement the run obeyed is the one the plan renders.
    let rendered = plan.render(true);
    for (m, &c) in plan.shard_of.iter().enumerate() {
        let line = format!("{} -> {}",
                           plan.chips[c].plan.models[m].name,
                           plan.chips[c].chip.name);
        assert!(rendered.contains(&line), "missing '{line}' in:\n{rendered}");
    }
}

/// `FleetRun` validates its inputs: unsorted traces and out-of-range model
/// indices are rejected with actionable messages.
#[test]
fn fleet_run_validates_its_trace() {
    let mix = ModelMix::uniform(vec![zoo::mini_cnn()]);
    let fleet = Fleet::parse("edge4").unwrap();
    let mut cache = PlanCache::new();
    let plan = plan_fleet(&fleet, &mix, None, 1, true, &mut cache).unwrap();
    let router = RouterConfig::new(RoutePolicy::RoundRobin);

    let unsorted = [Request { id: 0, model: 0, arrival_ms: 5.0 },
                    Request { id: 1, model: 0, arrival_ms: 1.0 }];
    let err =
        FleetRun::new(&plan, router).trace(&unsorted).run().unwrap_err();
    assert!(err.contains("sorted"), "{err}");

    let out_of_range = [Request { id: 0, model: 7, arrival_ms: 0.0 }];
    let err =
        FleetRun::new(&plan, router).trace(&out_of_range).run().unwrap_err();
    assert!(err.contains("model 7"), "{err}");
    assert!(err.contains("only 1"), "{err}");
}

/// The fleet's price tag: `cost_per_request` is exactly
/// `total chip-cores x makespan / completed` — pinned arithmetically
/// against the run's own numbers, rendered, and exported as a sim gauge.
#[test]
fn cost_per_request_is_pinned_to_the_run() {
    let mix = ModelMix::uniform(vec![zoo::alexnet(), zoo::mini_cnn()]);
    let fleet = Fleet::parse("mlu100,edge4x2").unwrap();
    let mut cache = PlanCache::new();
    let plan = plan_fleet(&fleet, &mix, None, 1, true, &mut cache).unwrap();
    let trace = serving::generate_trace(
        &mix, ArrivalProcess::OpenPoisson { rate_rps: 400.0 }, 160, 5);
    let result = FleetRun::new(&plan, RouterConfig::new(RoutePolicy::LeastLoaded))
        .trace(&trace)
        .run()
        .unwrap();
    let report = FleetReport::from_run(&result, &plan, Some(50.0));
    let expected = result.total_cores as f64 * report.slo.makespan_ms
        / result.completed() as f64;
    assert!(result.completed() > 0);
    assert_eq!(report.cost_per_request.to_bits(), expected.to_bits(),
               "cost_per_request {} != cores x makespan / completed {}",
               report.cost_per_request, expected);
    assert!(report.render().contains("cost per request"));
    let mut reg = MetricsRegistry::new();
    report.export_metrics(&mut reg);
    assert_eq!(reg.gauge("serving.cost_per_request"),
               Some(report.cost_per_request));
    // A bigger fleet retiring the same trace costs more core-ms per
    // request when the extra cores sit idle.
    let fleet2 = Fleet::parse("mlu100x2,edge4x2").unwrap();
    let plan2 = plan_fleet(&fleet2, &mix, None, 1, true, &mut cache).unwrap();
    let result2 =
        FleetRun::new(&plan2, RouterConfig::new(RoutePolicy::LeastLoaded))
            .trace(&trace)
            .run()
            .unwrap();
    let report2 = FleetReport::from_run(&result2, &plan2, Some(50.0));
    assert!(report2.cost_per_request.is_finite()
            && report2.cost_per_request > 0.0);
}
