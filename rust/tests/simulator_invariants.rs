//! Integration: cost-model invariants of the accelerator simulator —
//! the physics the optimizer's decisions rest on (DESIGN.md §6), checked
//! with randomized property tests.

use dlfusion::accel::{Simulator, Target};
use dlfusion::graph::layer::{ConvSpec, Layer};
use dlfusion::testutil::prop::{forall, Gen};
use dlfusion::util::XorShiftRng;

fn rand_conv(rng: &mut XorShiftRng) -> Layer {
    let c = 1usize << rng.gen_usize(3, 9);
    let hw = *rng.choose(&[7usize, 14, 28, 56, 112]);
    let k = *rng.choose(&[1usize, 3, 5]);
    Layer::conv("c", ConvSpec::same(c, c, hw, k))
}

#[test]
fn prop_latency_positive_finite_everywhere() {
    let sim = Simulator::new(Target::mlu100());
    let g = Gen::new(|rng: &mut XorShiftRng| (rand_conv(rng), 1usize << rng.gen_usize(0, 5)));
    forall(100, &g, |(l, mp)| {
        let t = sim.layer_latency_ms(l, *mp);
        if t.is_finite() && t > 0.0 { Ok(()) } else { Err(format!("latency {t}")) }
    });
}

#[test]
fn prop_latency_monotone_in_opcount_at_fixed_shape() {
    // Scaling a layer's channels up (4x the ops) cannot reduce latency.
    let sim = Simulator::new(Target::mlu100());
    let g = Gen::new(|rng: &mut XorShiftRng| {
        let c = 1usize << rng.gen_usize(3, 8);
        let hw = *rng.choose(&[14usize, 28, 56]);
        let mp = 1usize << rng.gen_usize(0, 5);
        (c, hw, mp)
    });
    forall(60, &g, |&(c, hw, mp)| {
        let small = Layer::conv("s", ConvSpec::same(c, c, hw, 3));
        let big = Layer::conv("b", ConvSpec::same(2 * c, 2 * c, hw, 3));
        let ts = sim.layer_latency_ms(&small, mp);
        let tb = sim.layer_latency_ms(&big, mp);
        if tb >= ts { Ok(()) } else { Err(format!("bigger faster: {tb} < {ts}")) }
    });
}

#[test]
fn prop_gflops_never_exceed_roofline() {
    let sim = Simulator::new(Target::mlu100());
    let g = Gen::new(|rng: &mut XorShiftRng| (rand_conv(rng), 1usize << rng.gen_usize(0, 5)));
    forall(100, &g, |(l, mp)| {
        let achieved = sim.layer_gflops(l, *mp);
        let bound = dlfusion::perfmodel::roofline::roofline_gflops(&sim.spec, l.intensity());
        if achieved <= bound * (1.0 + 1e-9) {
            Ok(())
        } else {
            Err(format!("achieved {achieved} > roofline {bound}"))
        }
    });
}

#[test]
fn prop_fusing_two_small_layers_beats_unfused_at_same_mp() {
    // The Fig. 7 benefit: for small layers fusion never loses at matched MP
    // (launch + fill amortization dominates the halo cost at depth 2).
    let sim = Simulator::new(Target::mlu100());
    let g = Gen::new(|rng: &mut XorShiftRng| {
        let c = 1usize << rng.gen_usize(4, 7);
        let hw = *rng.choose(&[28usize, 56]);
        let mp = 1usize << rng.gen_usize(0, 3);
        (c, hw, mp)
    });
    forall(40, &g, |&(c, hw, mp)| {
        let l = Layer::conv("c", ConvSpec::same(c, c, hw, 3));
        let layers = vec![l.clone(), l.clone()];
        let fused = sim.block_latency_ms(&layers, mp);
        let unfused = 2.0 * sim.layer_latency_ms(&l, mp);
        if fused <= unfused {
            Ok(())
        } else {
            Err(format!("fused {fused} > unfused {unfused}"))
        }
    });
}

#[test]
fn prop_block_redundancy_grows_with_mp() {
    use dlfusion::accel::fusion::block_redundant_gops;
    let g = Gen::new(|rng: &mut XorShiftRng| {
        let n = rng.gen_usize(2, 8);
        let c = 1usize << rng.gen_usize(4, 7);
        let hw = *rng.choose(&[28usize, 56]);
        (n, c, hw)
    });
    forall(40, &g, |&(n, c, hw)| {
        let layers: Vec<Layer> = (0..n)
            .map(|i| Layer::conv(format!("c{i}"), ConvSpec::same(c, c, hw, 3)))
            .collect();
        let mut last = 0.0;
        for mp in [1usize, 2, 4, 8, 16, 32] {
            let (total, _) = block_redundant_gops(&layers, mp);
            if total < last - 1e-9 {
                return Err(format!("redundant gops decreased at mp={mp}"));
            }
            last = total;
        }
        Ok(())
    });
}

#[test]
fn prop_memory_fused_traffic_at_most_unfused() {
    use dlfusion::accel::memory::{fused_block_traffic, unfused_layer_bytes};
    let sim = Simulator::new(Target::mlu100());
    let g = Gen::new(|rng: &mut XorShiftRng| {
        let n = rng.gen_usize(2, 6);
        let c = 1usize << rng.gen_usize(4, 7);
        let hw = *rng.choose(&[14usize, 28, 56]);
        let mp = 1usize << rng.gen_usize(2, 5);
        (n, c, hw, mp)
    });
    forall(40, &g, |&(n, c, hw, mp)| {
        let layers: Vec<Layer> = (0..n)
            .map(|i| Layer::conv(format!("c{i}"), ConvSpec::same(c, c, hw, 3)))
            .collect();
        let fused = fused_block_traffic(&sim.spec, &layers, mp).total();
        let unfused: f64 = layers.iter().map(unfused_layer_bytes).sum();
        // Even with spills, fused traffic can't exceed unfused (a spill
        // round-trips once; unfused round-trips every boundary).
        if fused <= unfused + 1e-6 {
            Ok(())
        } else {
            Err(format!("fused {fused} > unfused {unfused}"))
        }
    });
}

#[test]
fn best_mp_shifts_up_with_opcount() {
    // Fig. 4(c) in property form: optimal MP is non-decreasing as op count
    // scales through channel expansion (at fixed spatial size).
    let sim = Simulator::new(Target::mlu100());
    let mut last = 1;
    for factor in [1usize, 2, 4] {
        let layer = dlfusion::zoo::scaled_conv_layer(factor);
        let best = sim.best_layer_mp(&layer);
        assert!(best >= last, "factor {factor}: best {best} < {last}");
        last = best;
    }
}

#[test]
fn equal_ops_different_channels_different_best_mp() {
    // Fig. 6(a) in integration form.
    let sim = Simulator::new(Target::mlu100());
    let series = dlfusion::microbench::equal_ops_channel_series();
    let bests: Vec<usize> = series.iter().map(|(_, l)| sim.best_layer_mp(l)).collect();
    assert!(bests.iter().max() > bests.iter().min(),
            "channel width must move the optimum: {bests:?}");
}
