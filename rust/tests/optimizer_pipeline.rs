//! Integration: the optimize -> simulate pipeline across the zoo, plus
//! property tests over the optimizer's invariants (proptest substitute —
//! see `dlfusion::testutil::prop`).
#![allow(deprecated)] // exercises the legacy shims alongside the tuner API

use dlfusion::accel::{Simulator, Target};
use dlfusion::graph::layer::ConvSpec;
use dlfusion::graph::Model;
use dlfusion::optimizer::{self, AlgorithmParams, Schedule, Strategy};
use dlfusion::perfmodel::mp_select::MpModel;
use dlfusion::search;
use dlfusion::testutil::prop::{forall, Gen};
use dlfusion::util::XorShiftRng;
use dlfusion::zoo;

fn random_model(rng: &mut XorShiftRng) -> Model {
    let n = rng.gen_usize(1, 24);
    let c = 1usize << rng.gen_usize(4, 9);
    let hw = *rng.choose(&[14usize, 28, 56]);
    zoo::identical_conv_model("prop", ConvSpec::same(c, c, hw, 3), n)
}

#[test]
fn every_strategy_on_every_model_is_valid_and_consistent() {
    let sim = Simulator::new(Target::mlu100());
    for m in zoo::all_models() {
        for st in Strategy::ALL {
            let (sched, rep) = optimizer::run_strategy(&sim, &m, st);
            sched.validate(m.num_layers(), sim.spec.num_cores)
                .unwrap_or_else(|e| panic!("{} {st}: {e}", m.name));
            // Useful GOPs reported must equal the model total regardless of
            // the schedule.
            let total: f64 = m.layers.iter().map(|l| l.op_gops()).sum();
            assert!((rep.total_gops - total).abs() < 1e-9, "{} {st}", m.name);
        }
    }
}

#[test]
fn prop_dlfusion_partition_is_exact_cover() {
    let spec = Target::mlu100().into_spec();
    let g = Gen::new(random_model);
    forall(60, &g, |m| {
        let sched = optimizer::dlfusion_schedule(m, &spec);
        sched.validate(m.num_layers(), spec.num_cores)?;
        // Exact cover: every index in exactly one block.
        let mut seen = vec![false; m.num_layers()];
        for b in &sched.blocks {
            for i in b.start..b.end {
                if seen[i] {
                    return Err(format!("layer {i} covered twice"));
                }
                seen[i] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("uncovered layer".into());
        }
        Ok(())
    });
}

#[test]
fn prop_block_mps_are_pow2_in_range() {
    let spec = Target::mlu100().into_spec();
    let g = Gen::new(random_model);
    forall(60, &g, |m| {
        let sched = optimizer::dlfusion_schedule(m, &spec);
        for b in &sched.blocks {
            if !b.mp.is_power_of_two() || b.mp > spec.num_cores {
                return Err(format!("block MP {} invalid", b.mp));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_oracle_never_loses_to_dlfusion_modulo_quantization() {
    // The DP oracle optimizes a superset-ish space (reduced MP set, size
    // rule); allow the rule's quantization margin.
    let sim = Simulator::new(Target::mlu100());
    let g = Gen::new(|rng: &mut XorShiftRng| {
        let n = rng.gen_usize(2, 12);
        let c = 1usize << rng.gen_usize(5, 9);
        zoo::identical_conv_model("p", ConvSpec::same(c, c, 28, 3), n)
    });
    forall(12, &g, |m| {
        let (oracle, _) = search::oracle_schedule(&sim, m);
        let heuristic = optimizer::dlfusion_schedule(m, &sim.spec);
        let t_o = sim.run_schedule(m, &oracle).total_ms;
        let t_h = sim.run_schedule(m, &heuristic).total_ms;
        if t_o > t_h * 1.05 {
            return Err(format!("oracle {t_o} much worse than dlfusion {t_h}"));
        }
        Ok(())
    });
}

#[test]
fn prop_simulator_latency_monotone_in_depth() {
    // Adding layers to a model can't make the optimized whole-model run
    // faster.
    let sim = Simulator::new(Target::mlu100());
    let g = Gen::new(|rng: &mut XorShiftRng| {
        (rng.gen_usize(1, 12), 1usize << rng.gen_usize(5, 8))
    });
    forall(20, &g, |&(n, c)| {
        let small = zoo::identical_conv_model("s", ConvSpec::same(c, c, 28, 3), n);
        let big = zoo::identical_conv_model("b", ConvSpec::same(c, c, 28, 3), n + 2);
        let t_small = sim
            .run_schedule(&small, &optimizer::dlfusion_schedule(&small, &sim.spec))
            .total_ms;
        let t_big = sim
            .run_schedule(&big, &optimizer::dlfusion_schedule(&big, &sim.spec))
            .total_ms;
        if t_big < t_small * 0.999 {
            return Err(format!("deeper model faster: {t_big} < {t_small}"));
        }
        Ok(())
    });
}

#[test]
fn prop_fused_single_layer_equals_unfused() {
    let sim = Simulator::new(Target::mlu100());
    let g = Gen::new(|rng: &mut XorShiftRng| {
        let c = 1usize << rng.gen_usize(4, 9);
        let hw = *rng.choose(&[7usize, 14, 28, 56]);
        let mp = 1usize << rng.gen_usize(0, 5);
        (c, hw, mp)
    });
    forall(50, &g, |&(c, hw, mp)| {
        let m = zoo::identical_conv_model("x", ConvSpec::same(c, c, hw, 3), 1);
        let lw = Schedule::layerwise(m.num_layers(), mp);
        let sb: f64 = m
            .layers
            .iter()
            .map(|l| sim.layer_latency_ms(l, mp))
            .sum();
        let t = sim.run_schedule(&m, &lw).total_ms;
        if (t - sb).abs() > 1e-9 {
            return Err(format!("layerwise {t} != sum {sb}"));
        }
        Ok(())
    });
}

#[test]
fn critical_threshold_controls_block_count_monotonically() {
    let spec = Target::mlu100().into_spec();
    let m = zoo::identical_conv_model("t", ConvSpec::same(256, 256, 56, 3), 24);
    let mut last_blocks = usize::MAX;
    for crit in [0.1, 0.5, 2.0, 8.0, 1e6] {
        let params = AlgorithmParams { opcount_critical: crit, mp_model: MpModel::default() };
        let sched = optimizer::algorithm::dlfusion_schedule_with(&m, &spec, &params);
        assert!(sched.num_blocks() <= last_blocks,
                "blocks should shrink as critical grows");
        last_blocks = sched.num_blocks();
    }
    assert_eq!(last_blocks, 1);
}

#[test]
fn search_time_comparison_paper_claim() {
    // Paper Section V: DLFusion is O(n) while even the reduced brute force
    // is quadratic in evaluations. Verify the count relationship.
    let sim = Simulator::new(Target::mlu100());
    let m = zoo::resnet50();
    let (_, stats) = search::oracle_schedule(&sim, &m);
    // n = 174 layers; oracle considers O(n^2/16 * 8) evaluations.
    assert!(stats.evaluations > m.num_layers() * 8,
            "oracle evals {} suspiciously low", stats.evaluations);
    // Algorithm 1 performs exactly one pass (cannot observe directly here,
    // but its runtime is bounded): time it generously.
    let t0 = std::time::Instant::now();
    for _ in 0..100 {
        let _ = optimizer::dlfusion_schedule(&m, &sim.spec);
    }
    assert!(t0.elapsed().as_millis() < 1000, "Algorithm 1 should be O(n)-fast");
}
