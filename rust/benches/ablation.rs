//! Ablation: sensitivity of DLFusion to the constants Algorithm 1 / Eq. 5
//! hard-code — `OpCount_critical`, the Eq. 5 weights, the channel
//! granularity — plus the cost of the oracle's search-space reduction.
//! (Beyond-paper analysis; DESIGN.md §4 "additional benches".)

use dlfusion::accel::{AcceleratorSpec, Simulator};
use dlfusion::bench_harness::{banner, BENCH_OUT_DIR};
use dlfusion::optimizer::{algorithm, AlgorithmParams};
use dlfusion::perfmodel::mp_select::MpModel;
use dlfusion::search;
use dlfusion::util::csv::Csv;
use dlfusion::util::Table;
use dlfusion::zoo;

fn geomean_fps(sim: &Simulator, params: &AlgorithmParams) -> f64 {
    let fps: Vec<f64> = zoo::all_models()
        .iter()
        .map(|m| {
            let s = algorithm::dlfusion_schedule_with(m, &sim.spec, params);
            sim.run_schedule(m, &s).fps()
        })
        .collect();
    dlfusion::stats::descriptive::geomean(&fps)
}

fn main() {
    banner("Ablation", "sensitivity of DLFusion's constants (geomean FPS over the zoo)");
    let sim = Simulator::mlu100();
    let base = AlgorithmParams::for_spec(&sim.spec);
    let base_fps = geomean_fps(&sim, &base);

    // ---- OpCount_critical ----
    let mut t = Table::new(&["OpCount_critical (GOPs/core)", "geomean FPS", "vs default"])
        .label_first().with_title("Algorithm 1 threshold");
    let mut csv = Csv::new(&["knob", "value", "geomean_fps"]);
    for mult in [0.125, 0.25, 0.5, 1.0, 2.0, 4.0, 16.0] {
        let p = AlgorithmParams { opcount_critical: base.opcount_critical * mult, ..base };
        let f = geomean_fps(&sim, &p);
        t.row(vec![format!("{:.2}", p.opcount_critical), format!("{f:.0}"),
                   format!("{:+.1}%", 100.0 * (f / base_fps - 1.0))]);
        csv.row_display(&["critical".to_string(), format!("{:.3}", p.opcount_critical),
                          format!("{f:.1}")]);
    }
    println!("{t}");

    // ---- Eq. 5 weights ----
    let mut t = Table::new(&["(alpha, beta, bias)", "geomean FPS", "vs default"])
        .label_first().with_title("Eq. 5 weights");
    for (a, b_, c) in [(0.316, 0.659, 3.0), (0.659, 0.316, 3.0), (0.0, 0.659, 3.0),
                       (0.316, 0.0, 3.0), (0.316, 0.659, 2.0), (0.316, 0.659, 4.0)] {
        let p = AlgorithmParams {
            mp_model: MpModel { alpha: a, beta: b_, bias: c }, ..base
        };
        let f = geomean_fps(&sim, &p);
        t.row(vec![format!("({a}, {b_}, {c})"), format!("{f:.0}"),
                   format!("{:+.1}%", 100.0 * (f / base_fps - 1.0))]);
        csv.row_display(&["eq5".to_string(), format!("{a}/{b_}/{c}"), format!("{f:.1}")]);
    }
    println!("{t}");

    // ---- channel granularity (hardware what-if) ----
    let mut t = Table::new(&["granularity", "geomean FPS (DLFusion)"])
        .label_first().with_title("channel partition granularity");
    for g in [1usize, 4, 16, 64] {
        let mut spec = AcceleratorSpec::mlu100();
        spec.channel_granularity = g;
        let sim_g = Simulator::new(spec);
        let p = AlgorithmParams::for_spec(&sim_g.spec);
        let f = geomean_fps(&sim_g, &p);
        t.row(vec![g.to_string(), format!("{f:.0}")]);
        csv.row_display(&["granularity".to_string(), g.to_string(), format!("{f:.1}")]);
    }
    println!("{t}");

    // ---- generic stochastic search vs DLFusion (beyond-paper) ----
    let mut t = Table::new(&["network", "DLFusion FPS", "anneal FPS (2k moves)",
                             "anneal-from-DLFusion FPS"])
        .label_first()
        .with_title("simulated annealing over the unreduced space");
    for m in [zoo::resnet18(), zoo::alexnet()] {
        let dlf = algorithm::dlfusion_schedule_with(&m, &sim.spec, &base);
        let f_dlf = sim.run_schedule(&m, &dlf).fps();
        let cfg = search::annealing::AnnealConfig::default();
        let (_, cold_ms) = search::annealing::anneal(&sim, &m, &cfg, None);
        let (_, warm_ms) = search::annealing::anneal(&sim, &m, &cfg, Some(dlf));
        t.row(vec![m.name.clone(), format!("{f_dlf:.0}"),
                   format!("{:.0}", 1000.0 / cold_ms),
                   format!("{:.0}", 1000.0 / warm_ms)]);
        csv.row_display(&["annealing".to_string(), m.name.clone(),
                          format!("{:.3}", (1000.0 / cold_ms) / f_dlf)]);
    }
    println!("{t}");

    // ---- oracle reduction cost ----
    let mut t = Table::new(&["network", "reduced oracle FPS", "full-DP FPS", "reduction cost"])
        .label_first().with_title("what the paper's search-space reduction gives up");
    for m in [zoo::resnet18(), zoo::alexnet()] {
        let (red, _) = search::oracle_schedule(&sim, &m);
        let (full, _) = search::oracle_schedule_full(&sim, &m);
        let f_red = sim.run_schedule(&m, &red).fps();
        let f_full = sim.run_schedule(&m, &full).fps();
        t.row(vec![m.name.clone(), format!("{f_red:.0}"), format!("{f_full:.0}"),
                   format!("{:.1}%", 100.0 * (1.0 - f_red / f_full))]);
        csv.row_display(&["oracle_reduction".to_string(), m.name.clone(),
                          format!("{:.3}", f_red / f_full)]);
    }
    println!("{t}");
    csv.write_to(BENCH_OUT_DIR, "ablation").unwrap();
}
