//! Ablation: sensitivity of DLFusion to the constants Algorithm 1 / Eq. 5
//! hard-code — `OpCount_critical`, the Eq. 5 weights, the channel
//! granularity — plus the cost of the oracle's search-space reduction.
//! (Beyond-paper analysis; DESIGN.md §4 "additional benches".)

use dlfusion::accel::{Simulator, Target};
use dlfusion::bench_harness::{banner, BENCH_OUT_DIR};
use dlfusion::cost::CostEngine;
use dlfusion::optimizer::{algorithm, AlgorithmParams};
use dlfusion::perfmodel::mp_select::MpModel;
use dlfusion::search;
use dlfusion::tuner::{Algorithm1, Annealer, OracleDp, Tuner, TuningRequest};
use dlfusion::util::csv::Csv;
use dlfusion::util::Table;
use dlfusion::zoo;

/// Geomean FPS of DLFusion over the zoo, one memoized engine per network:
/// parameter sweeps re-evaluate mostly-overlapping schedules, so nearly
/// every block latency after the first sweep point is a cache hit.
fn geomean_fps(engines: &mut [CostEngine], params: &AlgorithmParams) -> f64 {
    let fps: Vec<f64> = engines
        .iter_mut()
        .map(|e| {
            let s = algorithm::dlfusion_schedule_with(e.model(), &e.sim().spec, params);
            e.run_schedule(&s).fps()
        })
        .collect();
    dlfusion::stats::descriptive::geomean(&fps)
}

fn main() {
    banner("Ablation", "sensitivity of DLFusion's constants (geomean FPS over the zoo)");
    let sim = Simulator::new(Target::mlu100());
    let models = zoo::all_models();
    let mut engines: Vec<CostEngine> =
        models.iter().map(|m| CostEngine::new(&sim, m)).collect();
    let base = AlgorithmParams::for_spec(&sim.spec);
    let base_fps = geomean_fps(&mut engines, &base);

    // ---- OpCount_critical ----
    let mut t = Table::new(&["OpCount_critical (GOPs/core)", "geomean FPS", "vs default"])
        .label_first().with_title("Algorithm 1 threshold");
    let mut csv = Csv::new(&["knob", "value", "geomean_fps"]);
    for mult in [0.125, 0.25, 0.5, 1.0, 2.0, 4.0, 16.0] {
        let p = AlgorithmParams { opcount_critical: base.opcount_critical * mult, ..base };
        let f = geomean_fps(&mut engines, &p);
        t.row(vec![format!("{:.2}", p.opcount_critical), format!("{f:.0}"),
                   format!("{:+.1}%", 100.0 * (f / base_fps - 1.0))]);
        csv.row_display(&["critical".to_string(), format!("{:.3}", p.opcount_critical),
                          format!("{f:.1}")]);
    }
    println!("{t}");

    // ---- Eq. 5 weights ----
    let mut t = Table::new(&["(alpha, beta, bias)", "geomean FPS", "vs default"])
        .label_first().with_title("Eq. 5 weights");
    for (a, b_, c) in [(0.316, 0.659, 3.0), (0.659, 0.316, 3.0), (0.0, 0.659, 3.0),
                       (0.316, 0.0, 3.0), (0.316, 0.659, 2.0), (0.316, 0.659, 4.0)] {
        let p = AlgorithmParams {
            mp_model: MpModel { alpha: a, beta: b_, bias: c }, ..base
        };
        let f = geomean_fps(&mut engines, &p);
        t.row(vec![format!("({a}, {b_}, {c})"), format!("{f:.0}"),
                   format!("{:+.1}%", 100.0 * (f / base_fps - 1.0))]);
        csv.row_display(&["eq5".to_string(), format!("{a}/{b_}/{c}"), format!("{f:.1}")]);
    }
    println!("{t}");

    // ---- channel granularity (hardware what-if) ----
    let mut t = Table::new(&["granularity", "geomean FPS (DLFusion)"])
        .label_first().with_title("channel partition granularity");
    for g in [1usize, 4, 16, 64] {
        let mut spec = Target::mlu100().into_spec();
        spec.channel_granularity = g;
        let sim_g = Simulator::from_spec(spec).expect("granularity sweep spec");
        // A different spec changes every latency: fresh engines required.
        let mut engines_g: Vec<CostEngine> =
            models.iter().map(|m| CostEngine::new(&sim_g, m)).collect();
        let p = AlgorithmParams::for_spec(&sim_g.spec);
        let f = geomean_fps(&mut engines_g, &p);
        t.row(vec![g.to_string(), format!("{f:.0}")]);
        csv.row_display(&["granularity".to_string(), g.to_string(), format!("{f:.1}")]);
    }
    println!("{t}");

    // ---- generic stochastic search vs DLFusion (beyond-paper) ----
    let mut t = Table::new(&["network", "DLFusion FPS", "anneal FPS (2k moves)",
                             "anneal-from-DLFusion FPS"])
        .label_first()
        .with_title("simulated annealing over the unreduced space");
    for m in [zoo::resnet18(), zoo::alexnet()] {
        // Cold anneal, warm anneal, and DLFusion all share one tuning
        // context (and so one memoized engine).
        let request = TuningRequest::new(&sim, &m)
            .anneal_config(search::AnnealConfig::default());
        let mut cx = request.context();
        let dlf = Algorithm1.tune(&mut cx).expect("tuning");
        let f_dlf = dlf.fps();
        let cold = Annealer::new().tune(&mut cx).expect("tuning");
        let warm = Annealer::from_schedule(dlf.schedule.clone())
            .tune(&mut cx)
            .expect("tuning");
        t.row(vec![m.name.clone(), format!("{f_dlf:.0}"),
                   format!("{:.0}", cold.fps()),
                   format!("{:.0}", warm.fps())]);
        csv.row_display(&["annealing".to_string(), m.name.clone(),
                          format!("{:.3}", cold.fps() / f_dlf)]);
        let st = cx.engine_stats();
        println!("  {}: {} block queries, {} computed ({:.0}x fewer raw \
                  evaluations than per-move re-simulation)",
                 m.name, st.queries(), st.misses, st.block_eval_reduction());
    }
    println!("{t}");

    // ---- oracle reduction cost ----
    let mut t = Table::new(&["network", "reduced oracle FPS", "full-DP FPS", "reduction cost"])
        .label_first().with_title("what the paper's search-space reduction gives up");
    for m in [zoo::resnet18(), zoo::alexnet()] {
        let request = TuningRequest::new(&sim, &m);
        let mut cx = request.context();
        let red = OracleDp::reduced().tune(&mut cx).expect("tuning");
        let full = OracleDp::full().tune(&mut cx).expect("tuning");
        let (f_red, f_full) = (red.fps(), full.fps());
        t.row(vec![m.name.clone(), format!("{f_red:.0}"), format!("{f_full:.0}"),
                   format!("{:.1}%", 100.0 * (1.0 - f_red / f_full))]);
        csv.row_display(&["oracle_reduction".to_string(), m.name.clone(),
                          format!("{:.3}", f_red / f_full)]);
    }
    println!("{t}");
    csv.write_to(BENCH_OUT_DIR, "ablation").unwrap();
}
