//! Serving-simulator throughput: the cost of the allocator's MP-cap sweep,
//! the event loop's processing rate, and the capacity gap between the
//! single-request-optimal and load-aware allocations.

use dlfusion::accel::{Simulator, Target};
use dlfusion::bench_harness::{banner, Bench, BENCH_OUT_DIR};
use dlfusion::serving::{self, AllocationRequest, ArrivalProcess,
                        ClusterConfig, DispatchPolicy, ModelMix,
                        SimulationRun};
use dlfusion::util::csv::Csv;
use dlfusion::util::Table;
use dlfusion::zoo;

fn main() {
    banner("serving", "multi-tenant serving: allocation sweep + event loop");
    let sim = Simulator::new(Target::mlu100());
    let mix = ModelMix::uniform(vec![zoo::resnet18(), zoo::alexnet()]);

    let mut b = Bench::new("serving_throughput");
    b.time("plan_allocations_2_models", || {
        AllocationRequest::new(&sim, &mix)
            .slo_ms(Some(50.0))
            .plan()
            .expect("allocation")
    });

    let plan = AllocationRequest::new(&sim, &mix)
        .slo_ms(Some(50.0))
        .plan()
        .expect("allocation");
    let trace = serving::generate_trace(
        &mix, ArrivalProcess::OpenPoisson { rate_rps: 800.0 }, 2000, 7);
    for policy in [DispatchPolicy::Fifo, DispatchPolicy::ShortestJobFirst] {
        let cfg = ClusterConfig { num_cores: sim.spec.num_cores, policy };
        b.time(&format!("simulate_2k_requests_{}", policy.name()), || {
            SimulationRun::new(&cfg, &plan.services(true))
                .trace(&trace)
                .run()
                .expect("simulate")
        });
    }
    // The hot path: same trace, event recording off (rust/docs/DESIGN.md
    // §12). `events_processed` is counted either way, so the rate is
    // events actually handled per second of wall time, not trace size.
    let cfg = ClusterConfig { num_cores: sim.spec.num_cores,
                              policy: DispatchPolicy::Fifo };
    b.time("simulate_2k_requests_fifo_no_trace", || {
        SimulationRun::new(&cfg, &plan.services(true))
            .trace(&trace)
            .record_events(false)
            .run()
            .expect("simulate")
    });
    let results = b.finish();
    let sim_ms = results[1].mean_ms();
    println!("\nevent loop: {:.0}k requests/s of simulator wall time",
             2000.0 / sim_ms);
    let hot = SimulationRun::new(&cfg, &plan.services(true))
        .trace(&trace)
        .record_events(false)
        .run()
        .expect("simulate");
    let hot_ms = results[3].mean_ms();
    println!("hot path (trace off): {:.0}k events/s \
              ({} events in {hot_ms:.2} ms)",
             hot.events_processed as f64 / hot_ms,
             hot.events_processed);

    // Capacity gap: predicted and simulated, per allocation objective.
    let mut t = Table::new(&["allocation", "capacity (pred)", "throughput (sim)",
                             "p99 e2e", "utilization"])
        .label_first()
        .with_title("single-request vs load-aware allocation under load");
    let mut csv = Csv::new(&["allocation", "predicted_capacity_rps",
                             "sim_throughput_rps", "p99_ms", "utilization"]);
    let cfg = ClusterConfig { num_cores: sim.spec.num_cores,
                              policy: DispatchPolicy::Fifo };
    let saturating = serving::generate_trace(
        &mix, ArrivalProcess::ClosedLoop { concurrency: 64 }, 1000, 7);
    for (label, load_aware) in [("single-request", false), ("load-aware", true)] {
        let r = SimulationRun::new(&cfg, &plan.services(load_aware))
            .trace(&saturating)
            .closed_loop(Some(64))
            .run()
            .expect("simulate");
        let rep = serving::SloReport::from_sim(&r, None);
        let p99 = rep.e2e.percentiles(&[99.0]).map_or(0.0, |p| p[0]);
        let cap = plan.predicted_capacity_rps(sim.spec.num_cores, load_aware);
        t.row(vec![
            label.to_string(),
            format!("{cap:.0} req/s"),
            format!("{:.0} req/s", rep.throughput_rps),
            format!("{p99:.2} ms"),
            format!("{:.1}%", 100.0 * rep.utilization),
        ]);
        csv.row_display(&[
            label.to_string(),
            format!("{cap:.1}"),
            format!("{:.1}", rep.throughput_rps),
            format!("{p99:.3}"),
            format!("{:.4}", rep.utilization),
        ]);
    }
    println!("{t}");
    csv.write_to(BENCH_OUT_DIR, "serving_throughput").unwrap();

    for m in plan.models.iter().filter(|m| m.diverged()) {
        println!("{}: load-aware MP {} ({:.3} ms) vs single-request MP {} \
                  ({:.3} ms)",
                 m.name, m.load_aware.cores, m.load_aware.service_ms,
                 m.single.cores, m.single.service_ms);
    }
}
