//! Dynamic batching: the per-sample amortization curve of the batch-aware
//! latency model, and the serving-level payoff of the `batch` dispatch
//! policy over one-request-at-a-time FIFO under overload.

use dlfusion::accel::{efficiency, Simulator, Target};
use dlfusion::bench_harness::{banner, Bench, BENCH_OUT_DIR};
use dlfusion::serving::{self, AllocationRequest, ArrivalProcess,
                        ClusterConfig, DispatchPolicy, ModelMix,
                        SimulationRun};
use dlfusion::tuner::{Algorithm1, Tuner, TuningRequest};
use dlfusion::util::csv::Csv;
use dlfusion::util::Table;
use dlfusion::zoo;

fn main() {
    banner("batching", "batch-aware cost model + dynamic-batching dispatch");
    let sim = Simulator::new(Target::mlu100());

    // ---- the amortization curve: one tuned schedule priced per batch ----
    let batches = [1usize, 2, 4, 8, 16, 32];
    let mut t = Table::new(&["model", "batch", "invocation", "per-sample",
                             "vs batch-1", "eta/core"])
        .label_first()
        .with_title("batched invocation latency (weights fetched once)");
    let mut csv = Csv::new(&["model", "batch", "invocation_ms", "per_sample_ms",
                             "core_efficiency"]);
    for model in [zoo::vgg19(), zoo::resnet50()] {
        let request = TuningRequest::new(&sim, &model);
        let mut cx = request.context();
        let outcome = Algorithm1.tune(&mut cx).expect("tuning");
        let t1 = outcome.predicted_ms;
        // Mean per-core op count of one block launch: the compute-side
        // amortization (pipeline fill paid once per launch) in isolation.
        let n = model.num_layers();
        let g_core = cx.engine_mut().facts().block_gops(0, n)
            / (outcome.schedule.num_blocks() * sim.spec.num_cores) as f64;
        for &b in &batches {
            let tb = cx.engine_mut().schedule_cost_at(&outcome.schedule, b);
            let per_sample = tb / b as f64;
            let eta = efficiency::core_efficiency_at_batch(&sim.spec, g_core, b);
            t.row(vec![
                model.name.clone(),
                b.to_string(),
                format!("{tb:.3} ms"),
                format!("{per_sample:.3} ms"),
                format!("{:.2}x", t1 / per_sample),
                format!("{:.1}%", 100.0 * eta),
            ]);
            csv.row_display(&[
                model.name.clone(),
                b.to_string(),
                format!("{tb:.4}"),
                format!("{per_sample:.4}"),
                format!("{eta:.4}"),
            ]);
        }
    }
    println!("{t}");
    csv.write_to(BENCH_OUT_DIR, "batching_amortization").unwrap();

    // ---- serving: batch policy vs FIFO under 2x-capacity overload ----
    let mix = ModelMix::uniform(vec![zoo::vgg19(), zoo::resnet18()]);
    let max_batch = serving::DEFAULT_MAX_BATCH;
    let plan = AllocationRequest::new(&sim, &mix)
        .max_batch(max_batch)
        .plan()
        .expect("allocation");
    let services = plan.services(true);
    let rate = 2.0 * plan.predicted_capacity_rps(sim.spec.num_cores, true);
    let slo = 3.0 * services
        .iter()
        .map(|s| s.service_at(max_batch))
        .fold(0.0, f64::max);
    let trace = serving::generate_trace(
        &mix, ArrivalProcess::OpenPoisson { rate_rps: rate }, 2000, 11);
    println!("offered {rate:.0} req/s (2x batch-1 capacity), SLO {slo:.1} ms, \
              predicted batched capacity {:.0} req/s",
             plan.predicted_batched_capacity_rps(sim.spec.num_cores));

    let mut b = Bench::new("batching_throughput");
    let mut t = Table::new(&["policy", "throughput", "goodput", "p99 e2e",
                             "utilization"])
        .label_first()
        .with_title("dynamic batching vs FIFO under overload");
    let mut csv = Csv::new(&["policy", "throughput_rps", "goodput_rps", "p99_ms",
                             "utilization"]);
    for (label, policy) in [
        ("fifo", DispatchPolicy::Fifo),
        ("batch", DispatchPolicy::Batch {
            max_batch,
            max_wait_ms: serving::DEFAULT_BATCH_WAIT_MS,
        }),
    ] {
        let cfg = ClusterConfig { num_cores: sim.spec.num_cores, policy };
        b.time(&format!("simulate_2k_requests_{label}"), || {
            SimulationRun::new(&cfg, &services)
                .trace(&trace)
                .run()
                .expect("simulate")
        });
        let result = SimulationRun::new(&cfg, &services)
            .trace(&trace)
            .run()
            .expect("simulate");
        let rep = serving::SloReport::from_sim(&result, Some(slo));
        let p99 = rep.e2e.percentiles(&[99.0]).map_or(0.0, |p| p[0]);
        t.row(vec![
            label.to_string(),
            format!("{:.0} req/s", rep.throughput_rps),
            format!("{:.0} req/s", rep.goodput_rps),
            format!("{p99:.2} ms"),
            format!("{:.1}%", 100.0 * rep.utilization),
        ]);
        csv.row_display(&[
            label.to_string(),
            format!("{:.1}", rep.throughput_rps),
            format!("{:.1}", rep.goodput_rps),
            format!("{p99:.3}"),
            format!("{:.4}", rep.utilization),
        ]);
    }
    b.finish();
    println!("{t}");
    csv.write_to(BENCH_OUT_DIR, "batching_throughput").unwrap();
}
