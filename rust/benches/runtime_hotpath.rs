//! L3 hot-path micro-benchmarks (§Perf): the operations on or near the
//! request path — schedule construction, simulation, plan building, PJRT
//! execution, and coordinator overhead vs raw execute.

use dlfusion::accel::{Simulator, Target};
use dlfusion::bench_harness::{banner, Bench};
use dlfusion::coordinator::{plan, Engine};
use dlfusion::optimizer;
use dlfusion::runtime::{artifact_dir, Runtime};
use dlfusion::zoo;

fn main() {
    banner("§Perf", "L3 hot-path microbenchmarks");
    let sim = Simulator::new(Target::mlu100());
    let resnet = zoo::resnet50();

    let mut b = Bench::new("optimizer").with_iters(3, 30);
    b.time("algorithm1_resnet50", || optimizer::dlfusion_schedule(&resnet, &sim.spec));
    let sched = optimizer::dlfusion_schedule(&resnet, &sim.spec);
    b.time("simulate_resnet50", || sim.run_schedule(&resnet, &sched));
    b.time("oracle_dp_resnet50", || {
        // Fresh engine per iteration: cold-cache DP time, as the old
        // engine-less wrapper measured.
        let mut engine = dlfusion::cost::CostEngine::new(&sim, &resnet);
        dlfusion::search::oracle_schedule_with(&mut engine)
    });
    b.time("codegen_resnet50", || dlfusion::codegen::generate_cpp(&resnet, &sched));
    b.finish();

    if !artifact_dir().join("manifest.json").exists() {
        println!("(artifacts not built; skipping PJRT hot-path section)");
        return;
    }
    let rt = Runtime::open_default().expect("runtime");
    let model = zoo::mini_cnn();
    let fused_sched = optimizer::dlfusion_schedule(&model, &sim.spec);
    let ex_plan = plan::build_plan(&model, &fused_sched, rt.manifest()).unwrap();
    let mut engine = Engine::new(rt, &model, ex_plan, 7).unwrap();
    // Warm the executables + get a request tensor.
    let x = engine.random_input(1);
    engine.infer(x.clone()).unwrap();

    let mut b = Bench::new("pjrt").with_iters(3, 20);
    b.time("infer_fused_mini_cnn", || engine.infer(x.clone()).unwrap());
    b.time("infer_unfused_mini_cnn", || engine.infer_unfused(x.clone()).unwrap());
    b.time("random_input", || engine.random_input(2));
    let results = b.finish();

    let fused = results[0].mean_ms();
    let unfused = results[1].mean_ms();
    println!("\nfused plan is {:.2}x the unfused per-stage path on PJRT CPU \
              wall-clock (fewer dispatches + no intermediate materialization)",
             unfused / fused);
}
