//! Fig. 7 — fusion depth trade-off.
//!
//! (b) fusing 4 vs 16 layers for Conv1 (1.72 GOPs) and Conv2 (0.43 GOPs):
//!     big layers lose from deep fusion, small layers win;
//! (c) speed-up ratio vs cores used for fused blocks, with the critical
//!     op count shifting down as cores increase.

use dlfusion::accel::{Simulator, Target};
use dlfusion::bench_harness::{banner, BENCH_OUT_DIR};
use dlfusion::graph::Layer;
use dlfusion::optimizer::Schedule;
use dlfusion::util::csv::Csv;
use dlfusion::util::Table;
use dlfusion::zoo;

fn main() {
    banner("Fig. 7(b)(c)", "fusion depth and core count trade-off");
    let sim = Simulator::new(Target::mlu100());
    let (conv1, conv2) = zoo::synthetic::fig7_convs();

    // ---- (b) 4-layer vs 16-layer fusion, MP=16 ----
    let mut t = Table::new(&["conv", "GOPs/layer", "B=4 FPS", "B=16 FPS", "winner"])
        .label_first()
        .with_title("Fig. 7(b) fusing 4 vs 16 identical layers (MP=16)");
    let mut csv = Csv::new(&["conv", "gops", "block", "fps"]);
    let mut winners = Vec::new();
    for (name, spec) in [("conv1", conv1), ("conv2", conv2)] {
        let m = zoo::identical_conv_model(name, spec, 16);
        let fps4 = sim.run_schedule(&m, &Schedule::uniform_blocks(m.num_layers(), 8, 16)).fps();
        let fps16 = sim.run_schedule(&m, &Schedule::single_block(m.num_layers(), 16)).fps();
        let g = Layer::conv("x", spec).op_gops();
        winners.push(if fps16 > fps4 { 16 } else { 4 });
        t.row(vec![name.into(), format!("{g:.2}"),
                   format!("{fps4:.0}"), format!("{fps16:.0}"),
                   format!("B={}", winners.last().unwrap())]);
        csv.row_display(&[name.to_string(), format!("{g:.3}"), "4".into(), format!("{fps4:.1}")]);
        csv.row_display(&[name.to_string(), format!("{g:.3}"), "16".into(), format!("{fps16:.1}")]);
    }
    println!("{t}");
    csv.write_to(BENCH_OUT_DIR, "fig7b_fusion_depth").unwrap();
    assert!(winners[1] >= winners[0],
            "the smaller conv must tolerate at least as deep fusion");

    // ---- (c) speed-up vs cores for a fused block, and the critical point ----
    let m = zoo::identical_conv_model("c", conv2, 8);
    let base = sim.run_schedule(&m, &Schedule::layerwise(m.num_layers(), 1)).total_ms;
    let mut t = Table::new(&["cores", "fused speed-up vs unfused MP=1",
                             "per-core computed GOPs"])
        .label_first()
        .with_title("Fig. 7(c) fused-block speed-up vs cores (8x conv2)");
    let mut csv = Csv::new(&["mp", "speedup", "per_core_gops"]);
    let mut speedups = Vec::new();
    for mp in [1usize, 2, 4, 8, 16, 32] {
        let fused = sim.run_schedule(&m, &Schedule::single_block(m.num_layers(), mp));
        let (computed, _) =
            dlfusion::accel::fusion::block_redundant_gops(&m.layers, mp);
        let speedup = base / fused.total_ms;
        speedups.push(speedup);
        t.row(vec![mp.to_string(), format!("{speedup:.2}x"),
                   format!("{:.2}", computed / mp as f64)]);
        csv.row_display(&[mp.to_string(), format!("{speedup:.3}"),
                          format!("{:.3}", computed / mp as f64)]);
    }
    println!("{t}");
    csv.write_to(BENCH_OUT_DIR, "fig7c_speedup_vs_cores").unwrap();
    assert!(speedups.iter().cloned().fold(0.0, f64::max) > speedups[0],
            "multi-core fusion must beat single-core fusion somewhere");
    println!("(fusion wins before the critical per-core op count, and more \
              cores shrink per-core op count while adding redundancy)");
}
