//! Fig. 8 — layer heterogeneity inside real networks.
//!
//! (a) per-layer optimal MP across ResNet-18 and VGG-19 (the spread that
//!     motivates grouping similar-MP layers);
//! (b) fusing layers with divergent optimal MPs underperforms fusing
//!     layers that agree.

use dlfusion::accel::{Simulator, Target};
use dlfusion::bench_harness::{banner, BENCH_OUT_DIR};
use dlfusion::graph::layer::ConvSpec;
use dlfusion::graph::{Layer, LayerKind};
use dlfusion::perfmodel::mp_select::MpModel;
use dlfusion::util::csv::Csv;
use dlfusion::util::Table;
use dlfusion::zoo;

fn main() {
    banner("Fig. 8", "per-layer optimal MP and mixed-MP fusion penalty");
    let sim = Simulator::new(Target::mlu100());
    let model = MpModel::default();

    // ---- (a) per-layer MP distribution ----
    let mut csv = Csv::new(&["network", "layer", "channels", "gops", "eq5_mp"]);
    let mut t = Table::new(&["network", "MP histogram (mp: count)"])
        .label_first()
        .with_title("Fig. 8(a) per-conv-layer MP selected by Eq. 5");
    for m in [zoo::resnet18(), zoo::vgg19()] {
        let mut hist: std::collections::BTreeMap<usize, usize> = Default::default();
        for l in m.layers.iter().filter(|l| matches!(l.kind, LayerKind::Conv(_))) {
            let mp = model.select_layer(&sim.spec, l);
            *hist.entry(mp).or_default() += 1;
            csv.row_display(&[m.name.clone(), l.name.clone(),
                              l.channels().to_string(),
                              format!("{:.3}", l.op_gops()), mp.to_string()]);
        }
        let pretty: Vec<String> =
            hist.iter().map(|(mp, n)| format!("{mp}:{n}")).collect();
        t.row(vec![m.name.clone(), pretty.join("  ")]);
        assert!(hist.len() >= 2, "{}: optimal MP must vary across layers", m.name);
    }
    println!("{t}");
    csv.write_to(BENCH_OUT_DIR, "fig8a_layer_mp").unwrap();

    // ---- (b) mixed-MP fusion penalty ----
    // Homogeneous block: four layers that all want the same MP.
    // Mixed block: two layers wanting small MP + two wanting large MP
    // (constructed per the paper's methodology: pick MPs first, then layer
    // parameters matching them).
    let wants_small = ConvSpec::same(16, 16, 112, 3); // narrow -> few cores
    let wants_large = ConvSpec::same(512, 512, 56, 3); // wide, big -> many
    let homo_small: Vec<Layer> =
        (0..4).map(|i| Layer::conv(format!("s{i}"), wants_small)).collect();
    let homo_large: Vec<Layer> =
        (0..4).map(|i| Layer::conv(format!("l{i}"), wants_large)).collect();
    let best_block_ms = |layers: &[Layer]| {
        (1..=32usize)
            .filter(|m| m.is_power_of_two())
            .map(|mp| sim.block_latency_ms(layers, mp))
            .fold(f64::MAX, f64::min)
    };
    // Mixed: interleave small/large (channel chain broken is fine for the
    // cost model: the simulator prices shapes, not weights).
    let mixed: Vec<Layer> = vec![
        homo_small[0].clone(), homo_large[0].clone(),
        homo_small[1].clone(), homo_large[1].clone(),
    ];
    let t_homo = best_block_ms(&homo_small[..2]) + best_block_ms(&homo_large[..2]);
    let t_mixed = best_block_ms(&mixed);
    let mut t = Table::new(&["grouping", "latency (ms)"])
        .label_first()
        .with_title("Fig. 8(b) fusing agreeing-MP vs divergent-MP layers");
    t.row(vec!["two homogeneous blocks (MP-matched)".into(), format!("{t_homo:.3}")]);
    t.row(vec!["one mixed block (single shared MP)".into(), format!("{t_mixed:.3}")]);
    println!("{t}");
    let mut csv = Csv::new(&["grouping", "ms"]);
    csv.row_display(&["homogeneous", &format!("{t_homo:.4}")]);
    csv.row_display(&["mixed", &format!("{t_mixed:.4}")]);
    csv.write_to(BENCH_OUT_DIR, "fig8b_mixed_mp").unwrap();
    assert!(t_mixed > t_homo,
            "divergent-MP fusion must underperform MP-matched grouping");
    println!("(grouping layers with similar optimal MP is what Algorithm 1's \
              avg-MP blocks exploit)");
}
