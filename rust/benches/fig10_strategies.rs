//! Fig. 10 — the headline result: FPS of the seven Table III strategies on
//! the five evaluated networks, plus DLFusion's speedup over the baseline
//! and its proximity to the brute-force oracle.

use dlfusion::accel::{Simulator, Target};
use dlfusion::bench_harness::{banner, Bench, BENCH_OUT_DIR};
use dlfusion::optimizer::Strategy;
use dlfusion::tuner::{OracleDp, TableStrategy, Tuner, TuningRequest};
use dlfusion::util::csv::Csv;
use dlfusion::util::Table;
use dlfusion::zoo;

fn main() {
    banner("Fig. 10", "FPS of strategies 1-7 across the Table II networks");
    let sim = Simulator::new(Target::mlu100());

    let mut header = vec!["network".to_string()];
    header.extend(Strategy::ALL.iter().map(|s| format!("S{}", s.index())));
    header.push("S6/S1".into());
    header.push("S6/S7".into());
    let hr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hr).label_first()
        .with_title("FPS by strategy (S6 = DLFusion, S7 = oracle)");
    let mut csv = Csv::new(&["network", "strategy_index", "strategy", "fps",
                             "speedup_vs_baseline"]);

    let mut speedups = Vec::new();
    let mut proximities = Vec::new();
    let mut total_queries = 0u64;
    let mut total_computed = 0u64;
    for m in zoo::all_models() {
        // One tuning context per network: the seven strategies (and the
        // oracle's DP inside strategy 7) share every block evaluation.
        let request = TuningRequest::new(&sim, &m);
        let mut cx = request.context();
        let mut fps = Vec::new();
        for st in Strategy::ALL {
            let out = TableStrategy(st).tune(&mut cx).expect("tuning");
            fps.push(out.fps());
            csv.row_display(&[m.name.clone(), st.index().to_string(),
                              st.name().to_string(), format!("{:.1}", out.fps()),
                              format!("{:.3}", out.fps() / fps[0])]);
        }
        let st = cx.engine_stats();
        total_queries += st.queries();
        total_computed += st.misses;
        let s6s1 = fps[5] / fps[0];
        let s6s7 = fps[5] / fps[6];
        speedups.push(s6s1);
        proximities.push(s6s7);
        let mut row = vec![m.name.clone()];
        row.extend(fps.iter().map(|f| format!("{f:.0}")));
        row.push(format!("{s6s1:.2}x"));
        row.push(format!("{:.0}%", 100.0 * s6s7));
        t.row(row);
    }
    println!("{t}");
    csv.write_to(BENCH_OUT_DIR, "fig10_strategies").unwrap();
    println!("\ncost engine across all strategies: {total_queries} block \
              queries, {total_computed} computed ({:.1}x fewer)",
             total_queries as f64 / total_computed.max(1) as f64);

    let max = speedups.iter().cloned().fold(0.0, f64::max);
    let min = speedups.iter().cloned().fold(f64::MAX, f64::min);
    println!("\nDLFusion speedup over baseline: {min:.2}x .. {max:.2}x \
              (paper: 3.6x .. 7.9x)");
    println!("DLFusion vs oracle: {:.0}% .. {:.0}% (paper: within 10%; our \
              oracle is an exact DP over the reduced space, strictly stronger \
              than the paper's sampled search — see EXPERIMENTS.md)",
             100.0 * proximities.iter().cloned().fold(f64::MAX, f64::min),
             100.0 * proximities.iter().cloned().fold(0.0, f64::max));

    // Search-time comparison (the O(n) vs brute-force claim).
    let mut b = Bench::new("fig10_search_time");
    let m = zoo::resnet50();
    b.time("dlfusion_algorithm1", || {
        dlfusion::optimizer::dlfusion_schedule(&m, &sim.spec)
    });
    let request = TuningRequest::new(&sim, &m);
    let mut last_stats = None;
    b.time("oracle_reduced_dp", || {
        // A fresh context per timing iteration: cold-cache search time.
        let out = request.run(&mut OracleDp::reduced()).expect("tuning");
        last_stats = Some(out.stats);
        out.schedule
    });
    let results = b.finish();
    let ratio = results[1].mean_ms() / results[0].mean_ms().max(1e-9);
    println!("oracle search costs {ratio:.0}x DLFusion's O(n) pass on ResNet-50");
    let ostats = last_stats.expect("oracle timed at least once");
    println!("oracle DP detail: {} blocks considered, {} (block, MP) \
              evaluations ({} computed / {} cached), {} us wall",
             ostats.blocks_considered, ostats.evaluations,
             ostats.cache_misses, ostats.cache_hits, ostats.wall_us);
}
