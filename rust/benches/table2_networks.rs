//! Table II — evaluated-network statistics, paper vs computed (Eq. 1/2 on
//! the zoo's fully-specified layer shapes).

use dlfusion::bench_harness::{banner, BENCH_OUT_DIR};
use dlfusion::graph::LayerKind;
use dlfusion::util::csv::Csv;
use dlfusion::util::Table;
use dlfusion::zoo;

fn main() {
    banner("Table II", "network op statistics: paper vs computed");
    let paper: &[(&str, f64, f64, usize)] = &[
        ("resnet18", 3.38, 0.169, 20),
        ("resnet50", 7.61, 0.144, 53),
        ("vgg19", 36.34, 2.27, 16),
        ("alexnet", 1.22, 0.244, 5),
        ("mobilenet_v2", 10.33, 0.199, 52),
    ];
    let mut t = Table::new(&["network", "paper total", "ours", "paper avg", "ours ",
                             "paper #conv", "ours  "])
        .label_first();
    let mut csv = Csv::new(&["network", "paper_total_gops", "computed_total_gops",
                             "paper_avg", "computed_avg", "paper_convs",
                             "computed_convs", "note"]);
    for (m, &(name, p_total, p_avg, p_convs)) in zoo::all_models().iter().zip(paper) {
        let s = m.stats();
        // MobileNet: the paper's total matches Eq. 1 without the group
        // reduction (depthwise counted dense) — report that convention.
        let (total, avg, note) = if name == "mobilenet_v2" {
            let dense: f64 = m.layers.iter().filter_map(|l| match &l.kind {
                LayerKind::Conv(c) => Some(c.op_gops_dense_equiv()),
                _ => None,
            }).sum();
            (dense, dense / s.num_conv as f64, "dense-equivalent Eq.1")
        } else {
            (s.total_conv_gops, s.avg_conv_gops, "")
        };
        t.row(vec![name.into(), format!("{p_total:.2}"), format!("{total:.2}"),
                   format!("{p_avg:.3}"), format!("{avg:.3}"),
                   p_convs.to_string(), s.num_conv.to_string()]);
        csv.row_display(&[name.to_string(), p_total.to_string(),
                          format!("{total:.3}"), p_avg.to_string(),
                          format!("{avg:.4}"), p_convs.to_string(),
                          s.num_conv.to_string(), note.to_string()]);
    }
    println!("{t}");
    csv.write_to(BENCH_OUT_DIR, "table2_networks").unwrap();
    println!("(group-aware MobileNetV2 is ~0.6 GOPs; Table II's 10.33 matches \
              the dense-equivalent convention — see EXPERIMENTS.md)");
}
