//! Fig. 3 — roofline model vs actual (simulated) performance.
//!
//! Regenerates the paper's scatter: for the synthesized conv + FC
//! microbenchmarks, operation intensity (Eq. 3) vs attainable roofline
//! GFLOPS and achieved GFLOPS, quantifying the gap that motivates going
//! beyond the roofline model.

use dlfusion::accel::{Simulator, Target};
use dlfusion::bench_harness::{banner, Bench, BENCH_OUT_DIR};
use dlfusion::microbench;
use dlfusion::perfmodel::roofline;
use dlfusion::util::csv::Csv;
use dlfusion::util::Table;

fn main() {
    banner("Fig. 3", "roofline vs actual performance (conv + FC microbenchmarks)");
    let sim = Simulator::new(Target::mlu100());
    let mut layers = microbench::conv_sweep();
    layers.extend(microbench::fc_sweep());

    let mut csv = Csv::new(&["layer", "intensity_ops_per_byte", "gops",
                             "roofline_gflops", "achieved_gflops", "gap_x"]);
    let mut gaps = Vec::new();
    for l in &layers {
        let intensity = l.intensity();
        let bound = roofline::roofline_gflops(&sim.spec, intensity);
        let achieved = sim.layer_gflops(l, 32);
        gaps.push(bound / achieved);
        csv.row_display(&[
            l.name.clone(),
            format!("{intensity:.2}"),
            format!("{:.4}", l.op_gops()),
            format!("{bound:.1}"),
            format!("{achieved:.1}"),
            format!("{:.2}", bound / achieved),
        ]);
    }
    let path = csv.write_to(BENCH_OUT_DIR, "fig3_roofline").unwrap();

    let mut t = Table::new(&["quantile", "roofline/achieved gap"]).label_first()
        .with_title("Fig. 3 gap distribution (the paper's motivating observation)");
    let mut sorted = gaps.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for (q, name) in [(10.0, "p10"), (50.0, "p50"), (90.0, "p90")] {
        let v = dlfusion::stats::descriptive::percentile_sorted(&sorted, q);
        t.row(vec![name.to_string(), format!("{v:.1}x")]);
    }
    println!("{t}");
    println!("ridge point: {:.0} ops/byte; {} layers swept; CSV -> {}",
             roofline::ridge_intensity(&sim.spec), layers.len(), path.display());
    assert!(dlfusion::stats::descriptive::percentile_sorted(&sorted, 50.0) > 1.5,
            "paper's observation: a significant roofline gap exists");

    // Also time the sweep itself (simulator throughput).
    let mut b = Bench::new("fig3");
    b.time("full_sweep", || {
        layers.iter().map(|l| sim.layer_gflops(l, 32)).sum::<f64>()
    });
    b.finish();
}
