//! Eq. 4 — joint search-space size, and why brute force is infeasible,
//! grounded against what the engine-backed DP oracle actually evaluates.

use dlfusion::accel::{Simulator, Target};
use dlfusion::bench_harness::{banner, Bench, BENCH_OUT_DIR};
use dlfusion::optimizer::space;
use dlfusion::tuner::{OracleDp, TuningRequest};
use dlfusion::util::csv::Csv;
use dlfusion::util::Table;
use dlfusion::zoo;

fn main() {
    banner("Eq. 4", "search-space size Space(n) and the reduction the oracle uses");
    let mut t = Table::new(&["n", "Space(n, 32)", "reduced (MP=8 choices, B%4)"])
        .label_first();
    let mut csv = Csv::new(&["n", "log10_space_full", "log10_space_reduced"]);
    for n in [5usize, 10, 20, 50, 100] {
        let full = space::search_space(n, 32);
        // Reduced: 8 MP choices, block sizes multiple of 4 -> effectively a
        // partition problem over n/4 superlayers.
        let reduced = space::search_space((n / 4).max(2), 8);
        t.row(vec![n.to_string(), format!("{full}"), format!("{reduced}")]);
        csv.row_display(&[n.to_string(), format!("{:.2}", full.log10()),
                          format!("{:.2}", reduced.log10())]);
    }
    println!("{t}");
    let s50 = space::search_space(50, 32);
    println!("\nSpace(50) = {s50} (paper: 8.17e75 — exact match)");
    println!("The DP oracle avoids enumerating either space: it visits \
              O(n^2/16 * 8) block evaluations for the same reduced-space optimum.");

    // Ground the asymptotic claim: what the engine-backed DP actually does.
    let sim = Simulator::new(Target::mlu100());
    let mut t = Table::new(&["network", "n", "log10 Space(n)", "DP (block,MP) evals",
                             "computed", "DP wall (us)"])
        .label_first()
        .with_title("Eq. 4 space vs the oracle's real evaluation count");
    for m in [zoo::alexnet(), zoo::resnet18(), zoo::resnet50()] {
        let n = m.num_layers();
        let out = TuningRequest::new(&sim, &m)
            .run(&mut OracleDp::reduced())
            .expect("tuning");
        let st = out.stats;
        t.row(vec![m.name.clone(), n.to_string(),
                   format!("{:.1}", space::search_space(n, 32).log10()),
                   st.evaluations.to_string(), st.cache_misses.to_string(),
                   st.wall_us.to_string()]);
    }
    println!("{t}");
    csv.write_to(BENCH_OUT_DIR, "eq4_space").unwrap();

    let mut b = Bench::new("eq4");
    b.time("space_n1000", || space::search_space(1000, 32));
    b.time("space_exact_n20", || space::search_space_exact(20, 32));
    b.finish();
}
