//! Fig. 4(c) — multi-core performance vs op count.
//!
//! The Section II.B.2 experiment: the VGG-19 base conv `{64,64,224x224,3x3}`
//! with its channel dimension expanded by factors, swept over core counts.
//! Large-op-count layers prefer many cores; small ones prefer few.

use dlfusion::accel::{Simulator, Target};
use dlfusion::bench_harness::{banner, BENCH_OUT_DIR};
use dlfusion::microbench;
use dlfusion::util::csv::Csv;
use dlfusion::util::Table;

fn main() {
    banner("Fig. 4(c)", "multi-core GFLOPS vs op count (channel-scaled VGG base conv)");
    let sim = Simulator::new(Target::mlu100());
    let factors = [1usize, 2, 4, 8];
    let layers = microbench::channel_scaled_series(&factors);
    let mps = [1usize, 2, 4, 8, 16, 32];

    let mut header = vec!["layer (xfactor)".to_string(), "GOPs".to_string()];
    header.extend(mps.iter().map(|m| format!("MP={m}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&header_refs).label_first()
        .with_title("Fig. 4(c) achieved GFLOPS by MP");
    let mut csv = Csv::new(&["factor", "gops", "mp", "gflops", "best"]);

    let mut best_mps = Vec::new();
    for (f, l) in factors.iter().zip(&layers) {
        let perfs: Vec<f64> = mps.iter().map(|&m| sim.layer_gflops(l, m)).collect();
        let best_idx = perfs.iter().enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        best_mps.push(mps[best_idx]);
        let mut row = vec![format!("x{f}"), format!("{:.1}", l.op_gops())];
        for (i, p) in perfs.iter().enumerate() {
            row.push(if i == best_idx { format!("[{p:.0}]") } else { format!("{p:.0}") });
        }
        t.row(row);
        for (&m, &p) in mps.iter().zip(&perfs) {
            csv.row_display(&[f.to_string(), format!("{:.2}", l.op_gops()),
                              m.to_string(), format!("{p:.1}"),
                              (m == mps[best_idx]).to_string()]);
        }
    }
    println!("{t}");
    println!("optimal MP per factor: {best_mps:?} (paper: grows with op count)");
    csv.write_to(BENCH_OUT_DIR, "fig4c_multi_core").unwrap();
    assert!(best_mps.windows(2).all(|w| w[1] >= w[0]),
            "larger op count must not prefer fewer cores");
}
