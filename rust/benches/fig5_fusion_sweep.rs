//! Fig. 5(b) — optimal fusion block size for three synthetic 16-layer CNNs
//! built from `{64,64,56x56,3x3}`, `{256,256,56x56,3x3}`,
//! `{512,512,28x28,3x3}` baseline convs. Bigger layers prefer smaller
//! fusion blocks (redundant halo computation overtakes the launch/fill
//! amortization sooner).

use dlfusion::accel::{Simulator, Target};
use dlfusion::bench_harness::{banner, BENCH_OUT_DIR};
use dlfusion::optimizer::Schedule;
use dlfusion::util::csv::Csv;
use dlfusion::util::Table;
use dlfusion::zoo;

fn main() {
    banner("Fig. 5(b)", "optimal fusion block size, three 16-conv stacks");
    let sim = Simulator::new(Target::mlu100());
    let models = zoo::synthetic::fig5b_models(16);
    let sizes = [1usize, 2, 4, 8, 16];

    let mut header = vec!["stack".to_string()];
    header.extend(sizes.iter().map(|s| format!("B={s}")));
    header.push("best".into());
    let hr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hr).label_first()
        .with_title("FPS by fusion block size (conv count per block; MP=16)");
    let mut csv = Csv::new(&["stack", "block_convs", "fps"]);

    let mut bests = Vec::new();
    for m in &models {
        // Each conv is followed by a ReLU: block of B convs = 2B layers.
        let fps: Vec<f64> = sizes.iter()
            .map(|&bsz| {
                let sched = Schedule::uniform_blocks(m.num_layers(), 2 * bsz, 16);
                sim.run_schedule(m, &sched).fps()
            })
            .collect();
        let bi = fps.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        bests.push(sizes[bi]);
        let mut row = vec![m.name.clone()];
        row.extend(fps.iter().map(|f| format!("{f:.0}")));
        row.push(format!("B={}", sizes[bi]));
        t.row(row);
        for (&s, &f) in sizes.iter().zip(&fps) {
            csv.row_display(&[m.name.clone(), s.to_string(), format!("{f:.1}")]);
        }
    }
    println!("{t}");
    csv.write_to(BENCH_OUT_DIR, "fig5b_fusion_sweep").unwrap();
    println!("optimal block sizes (convs): {bests:?} \
              (paper: smaller optimal blocks for bigger convs)");
    assert!(bests[0] >= bests[2],
            "the 64-ch stack must tolerate at least as deep fusion as the 512-ch stack");
}
