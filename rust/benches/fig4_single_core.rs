//! Fig. 4(a)/(b) — single-core performance characterization.
//!
//! (a) achieved GFLOPS vs op count, with the per-bucket error bars the
//!     paper attributes to channel variation;
//! (b) one-factor sweeps: channel vs kernel size vs feature size influence
//!     with the other parameters fixed.

use dlfusion::accel::{Simulator, Target};
use dlfusion::bench_harness::{banner, BENCH_OUT_DIR};
use dlfusion::graph::layer::ConvSpec;
use dlfusion::graph::Layer;
use dlfusion::microbench;
use dlfusion::stats::Summary;
use dlfusion::util::csv::Csv;
use dlfusion::util::Table;

fn main() {
    banner("Fig. 4(a)(b)", "single-core GFLOPS vs op count; per-parameter influence");
    let sim = Simulator::new(Target::mlu100());

    // ---- (a): bucket the sweep by log10(op count) ----
    let layers = microbench::conv_sweep();
    let mut buckets: std::collections::BTreeMap<i64, Vec<f64>> = Default::default();
    for l in &layers {
        let b = (l.op_gops().log10() * 2.0).round() as i64; // half-decade bins
        buckets.entry(b).or_default().push(sim.layer_gflops(l, 1));
    }
    let mut t = Table::new(&["op count bin", "mean GFLOPS", "std (error bar)", "n"])
        .label_first()
        .with_title("Fig. 4(a) single-core performance vs op count");
    let mut csv = Csv::new(&["log10_gops_bin", "mean_gflops", "std_gflops", "n"]);
    let mut means = Vec::new();
    for (bin, vals) in &buckets {
        let s = Summary::of(vals);
        means.push(s.mean);
        t.row(vec![
            format!("10^{:.1} GOPs", *bin as f64 / 2.0),
            format!("{:.1}", s.mean),
            format!("{:.1}", s.std),
            s.n.to_string(),
        ]);
        csv.row_display(&[*bin as f64 / 2.0, s.mean, s.std, s.n as f64]);
    }
    println!("{t}");
    csv.write_to(BENCH_OUT_DIR, "fig4a_single_core").unwrap();
    assert!(means.windows(2).all(|w| w[1] >= w[0] * 0.95),
            "performance rises with op count");

    // ---- (b): one-factor influence ----
    let base = ConvSpec::same(128, 128, 56, 3);
    let channel: Vec<Layer> = [16usize, 32, 64, 128, 256, 512].iter()
        .map(|&c| Layer::conv(format!("ch{c}"), ConvSpec { c_in: c, c_out: c, ..base }))
        .collect();
    let kernel: Vec<Layer> = [1usize, 3, 5, 7].iter()
        .map(|&k| Layer::conv(format!("k{k}"), ConvSpec { k, pad: k / 2, ..base }))
        .collect();
    let feature: Vec<Layer> = [14usize, 28, 56, 112].iter()
        .map(|&h| Layer::conv(format!("f{h}"), ConvSpec { h_in: h, w_in: h, ..base }))
        .collect();

    let mut t = Table::new(&["factor", "GFLOPS range (min..max)", "spread per op-count decade"])
        .label_first()
        .with_title("Fig. 4(b) per-parameter influence (others fixed)");
    let mut csv = Csv::new(&["factor", "setting", "gops", "gflops"]);
    for (name, series) in [("channel", &channel), ("kernel", &kernel), ("feature", &feature)] {
        let perf: Vec<f64> = series.iter().map(|l| sim.layer_gflops(l, 1)).collect();
        let gops: Vec<f64> = series.iter().map(|l| l.op_gops()).collect();
        for (l, (&g, &p)) in series.iter().zip(gops.iter().zip(&perf)) {
            csv.row_display(&[name.to_string(), l.name.clone(),
                              format!("{g:.4}"), format!("{p:.1}")]);
        }
        let (min, max) = (perf.iter().cloned().fold(f64::MAX, f64::min),
                          perf.iter().cloned().fold(0.0, f64::max));
        // Normalize spread by how much of it is just op-count change.
        let decades = (gops.iter().cloned().fold(0.0, f64::max)
            / gops.iter().cloned().fold(f64::MAX, f64::min)).log10().max(1e-9);
        t.row(vec![name.to_string(),
                   format!("{min:.0} .. {max:.0}"),
                   format!("{:.2}", (max / min).log10() / decades)]);
    }
    println!("{t}");
    csv.write_to(BENCH_OUT_DIR, "fig4b_influence").unwrap();
    println!("(paper: channel has non-negligible influence; kernel/feature mostly \
              act through op count)");
}
