//! Fig. 6 — why Eq. 5 needs both features.
//!
//! (a) same op count, different channel widths -> different optimal MP;
//! (b) same channels, different op counts -> different optimal MP.

use dlfusion::accel::{Simulator, Target};
use dlfusion::bench_harness::{banner, BENCH_OUT_DIR};
use dlfusion::microbench;
use dlfusion::perfmodel::mp_select::MpModel;
use dlfusion::util::csv::Csv;
use dlfusion::util::Table;

fn main() {
    banner("Fig. 6", "optimal MP: fixed op count vs fixed channel sweeps");
    let sim = Simulator::new(Target::mlu100());
    let model = MpModel::default();

    // ---- (a) fixed op count ----
    let series = microbench::equal_ops_channel_series();
    let mut t = Table::new(&["channels", "GOPs", "simulator best MP", "Eq.5 MP"])
        .label_first()
        .with_title("Fig. 6(a) equal op count, varying channels");
    let mut csv = Csv::new(&["channels", "gops", "best_mp", "eq5_mp"]);
    let mut best_a = Vec::new();
    for (c, l) in &series {
        let best = sim.best_layer_mp(l);
        let pred = model.select_layer(&sim.spec, l);
        best_a.push(best);
        t.row(vec![c.to_string(), format!("{:.2}", l.op_gops()),
                   best.to_string(), pred.to_string()]);
        csv.row_display(&[c.to_string(), format!("{:.3}", l.op_gops()),
                          best.to_string(), pred.to_string()]);
    }
    println!("{t}");
    csv.write_to(BENCH_OUT_DIR, "fig6a_equal_ops").unwrap();
    assert!(best_a.first() < best_a.last(),
            "narrow layers must prefer fewer cores at equal op count");

    // ---- (b) fixed channels ----
    let series = microbench::fixed_channel_op_series(128);
    let mut t = Table::new(&["feature size", "GOPs", "simulator best MP", "Eq.5 MP"])
        .label_first()
        .with_title("Fig. 6(b) fixed channels (128), varying op count");
    let mut csv = Csv::new(&["hw", "gops", "best_mp", "eq5_mp"]);
    let mut best_b = Vec::new();
    for l in &series {
        let best = sim.best_layer_mp(l);
        let pred = model.select_layer(&sim.spec, l);
        best_b.push(best);
        t.row(vec![format!("{}x{}", l.input_shape().h, l.input_shape().w),
                   format!("{:.3}", l.op_gops()),
                   best.to_string(), pred.to_string()]);
        csv.row_display(&[l.input_shape().h.to_string(),
                          format!("{:.4}", l.op_gops()),
                          best.to_string(), pred.to_string()]);
    }
    println!("{t}");
    csv.write_to(BENCH_OUT_DIR, "fig6b_fixed_channel").unwrap();
    assert!(best_b.first() < best_b.last(),
            "op count must move the optimum at fixed channels");
    println!("(both features are necessary -> the joint Eq. 5 model)");
}
