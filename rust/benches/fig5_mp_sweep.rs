//! Fig. 5(a) — optimal uniform MP per network (all layers share one MP,
//! no fusion). Paper: ResNet-18 peaks at a small MP (4), VGG-19 at a
//! large one (16).

use dlfusion::accel::{Simulator, Target};
use dlfusion::bench_harness::{banner, BENCH_OUT_DIR};
use dlfusion::optimizer::Schedule;
use dlfusion::util::csv::Csv;
use dlfusion::util::Table;
use dlfusion::zoo;

fn main() {
    banner("Fig. 5(a)", "optimal uniform MP per network (no fusion)");
    let sim = Simulator::new(Target::mlu100());
    let mps = [1usize, 2, 4, 8, 12, 16, 24, 32];

    let mut header = vec!["network".to_string()];
    header.extend(mps.iter().map(|m| format!("MP={m}")));
    header.push("best".into());
    let hr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hr).label_first().with_title("FPS by uniform MP");
    let mut csv = Csv::new(&["network", "mp", "fps"]);

    let mut best = std::collections::BTreeMap::new();
    for m in zoo::all_models() {
        let fps: Vec<f64> = mps.iter()
            .map(|&mp| {
                let r = sim.run_schedule(&m, &Schedule::layerwise(m.num_layers(), mp));
                r.fps()
            })
            .collect();
        let bi = fps.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        best.insert(m.name.clone(), mps[bi]);
        let mut row = vec![m.name.clone()];
        row.extend(fps.iter().map(|f| format!("{f:.0}")));
        row.push(format!("MP={}", mps[bi]));
        t.row(row);
        for (&mp, &f) in mps.iter().zip(&fps) {
            csv.row_display(&[m.name.clone(), mp.to_string(), format!("{f:.1}")]);
        }
    }
    println!("{t}");
    csv.write_to(BENCH_OUT_DIR, "fig5a_mp_sweep").unwrap();
    println!("paper: ResNet-18 optimal 4, VGG-19 optimal 16 — measured: \
              resnet18={} vgg19={}", best["resnet18"], best["vgg19"]);
    assert!(best["vgg19"] > best["resnet18"],
            "high-op-count VGG must prefer more cores than ResNet-18");
}
