//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Pipeline (every arrow is real code, no mocks):
//!
//! 1. build a small CNN ([`zoo::mini_cnn`]: six 3x3 conv+ReLU stages);
//! 2. run **DLFusion** (Algorithm 1) over it — the paper's contribution;
//! 3. emit the CNML-style C++ the paper's code generator produces;
//! 4. map the schedule onto the AOT artifact catalog (Pallas fused-conv
//!    kernels lowered by `make artifacts`) and execute the *fused* plan and
//!    the *unfused* per-layer plan through the PJRT CPU runtime, asserting
//!    mathematical equivalence — DLFusion's correctness claim;
//! 5. serve a batched request loop on the fused plan, measuring wall-clock
//!    latency/throughput;
//! 6. print the simulated Fig. 10-style strategy row for the same model.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_inference
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use dlfusion::accel::{Simulator, Target};
use dlfusion::coordinator::{driver, equivalence, plan, Engine};
use dlfusion::optimizer::Strategy;
use dlfusion::runtime::Runtime;
use dlfusion::tuner::{Algorithm1, TableStrategy, Tuner, TuningRequest};
use dlfusion::util::Table;
use dlfusion::zoo;

fn main() {
    let model = zoo::mini_cnn();
    let sim = Simulator::new(Target::mlu100());

    // ---- (2) optimize: Algorithm 1 through the unified tuner API ----
    let request = TuningRequest::new(&sim, &model);
    let outcome = request.run(&mut Algorithm1).expect("tuning");
    let schedule = outcome.schedule.clone();
    println!("== DLFusion schedule for {} (tuner {}) ==",
             model.name, outcome.tuner);
    println!("   {}\n", schedule.summary());

    // ---- (3) codegen ----
    let cpp = dlfusion::codegen::generate_cpp(&model, &schedule);
    let out_dir = std::path::Path::new("generated");
    std::fs::create_dir_all(out_dir).expect("mkdir generated/");
    std::fs::write(out_dir.join("mini_cnn_inference.cpp"), &cpp).unwrap();
    std::fs::write(out_dir.join("cnml_compat.h"),
                   dlfusion::codegen::generate_header()).unwrap();
    println!("== generated CNML-style C++ -> generated/mini_cnn_inference.cpp ==");
    println!("   ({} lines, {} fused operators)\n",
             cpp.lines().count(),
             cpp.matches("cnmlCompileFusionOperator").count());

    // ---- (4) PJRT equivalence ----
    let mut rt = Runtime::open_default().unwrap_or_else(|e| {
        eprintln!("error: {e}\nrun `make artifacts` first");
        std::process::exit(1);
    });
    println!("== PJRT runtime: platform {} ==", rt.platform());
    let eq = equivalence::check_fused_vs_unfused(&mut rt, 42).expect("equivalence run");
    for c in &eq.checks {
        println!("   fused vs unfused {:<22} max|diff| {:.3e}  [{}]",
                 c.artifact, c.max_abs_diff, if c.passed { "ok" } else { "FAIL" });
    }
    assert!(eq.all_passed(), "fusion must be mathematically equivalent");
    let gold = equivalence::check_golden(&mut rt, 1e-4).expect("golden run");
    for c in &gold.checks {
        println!("   golden replay    {:<22} max|diff| {:.3e}  [{}]",
                 c.artifact, c.max_abs_diff, if c.passed { "ok" } else { "FAIL" });
    }
    assert!(gold.all_passed(), "golden vectors must replay");
    println!();

    // ---- (5) request loop ----
    let ex_plan = plan::build_plan(&model, &schedule, rt.manifest()).expect("plan");
    println!("== execution plan: {} steps ({} fused) ==",
             ex_plan.steps.len(), ex_plan.num_fused_steps());
    for s in &ex_plan.steps {
        println!("   step: {:<12} convs {:?} (block {}, MP {})",
                 s.artifact, s.conv_indices, s.block_index, s.mp);
    }
    let mut engine = Engine::new(rt, &model, ex_plan, 7).expect("engine");
    let cfg = driver::DriverConfig { requests: 64, warmup: 8, seed: 11, verify_each: true };
    let tuned = driver::serve_tuned(&mut engine, &cfg, &outcome).expect("serve");
    let rep = &tuned.report;
    println!("\n== request loop (PJRT CPU wall-clock) ==");
    println!("   {}", rep.latency.report());
    println!("   throughput: {:.1} inferences/s", rep.fps());
    println!("   simulator-predicted MLU100 latency: {:.4} ms/inference",
             tuned.predicted_ms);
    println!("   per-request equivalence: {} ok / {} failures",
             rep.counters.get("equivalence_ok"),
             rep.counters.get("equivalence_failures"));
    assert_eq!(rep.counters.get("equivalence_failures"), 0);

    // ---- (6) simulated strategy comparison, one shared tuning context ----
    let mut cx = request.context();
    let mut t = Table::new(&["#", "strategy", "FPS (sim)", "speedup"])
        .label_first()
        .with_title("\nFig. 10-style row — mini_cnn on the MLU100 simulator");
    let mut base = None;
    for st in Strategy::ALL {
        let out = TableStrategy(st).tune(&mut cx).expect("tuning");
        let b = *base.get_or_insert(out.fps());
        t.row(vec![st.index().to_string(), st.name().into(),
                   format!("{:.0}", out.fps()), format!("{:.2}x", out.fps() / b)]);
    }
    println!("{t}");
    println!("\ne2e OK");
}
