//! Code-generation demo: `.dlm` model description in → optimized CNML-style
//! C++ out (the paper's Fig. 9 tool-chain path: model file → parser →
//! optimizer → code generator).
//!
//! ```bash
//! cargo run --release --example codegen_demo
//! ```

use dlfusion::accel::{Simulator, Target};
use dlfusion::graph::format::{from_dlm, to_dlm};
use dlfusion::optimizer;
use dlfusion::zoo;

const DEMO_DLM: &str = r#"{
  "name": "demo_net",
  "input": [56, 56, 64],
  "layers": [
    {"name": "conv1", "op": "conv", "c_in": 64, "c_out": 64,
     "h_in": 56, "w_in": 56, "k": 3, "stride": 1, "pad": 1, "groups": 1},
    {"name": "relu1", "op": "relu", "shape": [56, 56, 64]},
    {"name": "conv2", "op": "conv", "c_in": 64, "c_out": 128,
     "h_in": 56, "w_in": 56, "k": 3, "stride": 2, "pad": 1, "groups": 1},
    {"name": "bn2", "op": "batchnorm", "shape": [28, 28, 128]},
    {"name": "relu2", "op": "relu", "shape": [28, 28, 128]},
    {"name": "conv3", "op": "conv", "c_in": 128, "c_out": 128,
     "h_in": 28, "w_in": 28, "k": 3, "stride": 1, "pad": 1, "groups": 1},
    {"name": "relu3", "op": "relu", "shape": [28, 28, 128]},
    {"name": "pool", "op": "pool", "shape": [28, 28, 128], "k": 2, "stride": 2},
    {"name": "fc", "op": "fc", "k": 25088, "n": 10}
  ]
}"#;

fn main() {
    // Parse the ONNX-substitute model description (DESIGN.md §2).
    let model = from_dlm(DEMO_DLM).expect("valid .dlm");
    println!("parsed '{}': {} layers, {} convs, {:.3} GOPs",
             model.name, model.num_layers(), model.stats().num_conv,
             model.stats().total_conv_gops);

    // Optimize and generate.
    let sim = Simulator::new(Target::mlu100());
    let sched = optimizer::dlfusion_schedule(&model, &sim.spec);
    println!("schedule: {}", sched.summary());
    let report = sim.run_schedule(&model, &sched);
    println!("simulated: {:.2} ms -> {:.0} FPS", report.total_ms, report.fps());

    let dir = std::path::Path::new("generated");
    std::fs::create_dir_all(dir).unwrap();
    let cpp = dlfusion::codegen::generate_cpp(&model, &sched);
    std::fs::write(dir.join("demo_net_inference.cpp"), &cpp).unwrap();
    std::fs::write(dir.join("cnml_compat.h"), dlfusion::codegen::generate_header()).unwrap();
    println!("wrote generated/demo_net_inference.cpp ({} lines)", cpp.lines().count());

    // Round-trip: export a zoo model to .dlm for editing.
    let resnet = zoo::resnet18();
    let text = to_dlm(&resnet);
    std::fs::write(dir.join("resnet18.dlm"), &text).unwrap();
    println!("wrote generated/resnet18.dlm ({} bytes) — feed it back with \
              `dlfusion optimize generated/resnet18.dlm`", text.len());
}
