//! Quickstart: the 10-line DLFusion API tour.
//!
//! Loads a zoo model, runs Algorithm 1, and simulates the optimized
//! schedule against the no-optimization baseline.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dlfusion::prelude::*;

fn main() {
    let spec = AcceleratorSpec::mlu100();
    let sim = Simulator::new(spec.clone());
    let model = zoo::resnet18();

    // The paper's contribution: joint fusion + MP auto-tuning in O(n).
    let schedule = optimizer::dlfusion_schedule(&model, &spec);
    println!("model:    {} ({} layers, {} convs)",
             model.name, model.num_layers(), model.stats().num_conv);
    println!("schedule: {}", schedule.summary());

    let optimized = sim.run_schedule(&model, &schedule);
    let baseline = sim.run_schedule(
        &model,
        &optimizer::Schedule::layerwise(model.num_layers(), 1),
    );
    println!("baseline:  {:8.1} FPS", baseline.fps());
    println!("DLFusion:  {:8.1} FPS  ({:.1}x speedup)",
             optimized.fps(), optimized.fps() / baseline.fps());
}
