//! Quickstart: the 10-line DLFusion API tour.
//!
//! Builds one declarative `TuningRequest`, runs Algorithm 1 through the
//! unified tuner API, and compares against the no-optimization baseline
//! (Table III strategy 1) through the same surface.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dlfusion::prelude::*;

fn main() {
    // Every run is for an explicit hardware target; `mlu100` is the paper's
    // Table I point (`dlfusion targets` lists the registry).
    let target = Target::by_name("mlu100").expect("registry target");
    let sim = Simulator::new(target);
    let model = zoo::resnet18();
    let request = TuningRequest::new(&sim, &model);

    // The paper's contribution: joint fusion + MP auto-tuning in O(n).
    let outcome = request.run(&mut Algorithm1).expect("tuning");
    println!("model:    {} ({} layers, {} convs)",
             model.name, model.num_layers(), model.stats().num_conv);
    println!("schedule: {}", outcome.schedule.summary());

    let baseline = request
        .run(&mut TableStrategy(Strategy::NonOptimization))
        .expect("tuning");
    println!("baseline:  {:8.1} FPS", baseline.fps());
    println!("DLFusion:  {:8.1} FPS  ({:.1}x speedup)",
             outcome.fps(), outcome.fps() / baseline.fps());
}
