//! Re-derive the paper's empirical constants from microbenchmarks — the
//! Section II methodology as a runnable program.
//!
//! The paper: (1) sweeps synthesized layers to find that achieved GFLOPS
//! saturates at `OpCount_critical = 10^1.25` GOPs per core; (2) runs PCA
//! over layer features to find op count (1st) and channel (2nd) dominate;
//! (3) fits the Eq. 5 MP-selection weights α = 0.316, β = 0.659. This
//! example repeats all three steps against the simulator substrate and
//! prints paper-vs-derived values.
//!
//! ```bash
//! cargo run --release --example characterize
//! ```

use dlfusion::accel::{Simulator, Target};
use dlfusion::microbench;
use dlfusion::perfmodel::{critical, features, mp_select::MpModel};
use dlfusion::util::units::fmt_gops;
use dlfusion::util::Table;

fn main() {
    let sim = Simulator::new(Target::mlu100());
    println!("characterizing {} via synthesized microbenchmarks\n", sim.spec.name);

    // ---- step 1: single-core saturation (Fig. 3(b) / 4(a)) ----
    let sweep = critical::single_core_sweep(&sim, 48);
    let mut t = Table::new(&["op count", "achieved GFLOPS"]).label_first()
        .with_title("single-core sweep (subsample)");
    for p in sweep.iter().step_by(6) {
        t.row(vec![fmt_gops(p.gops), format!("{:.1}", p.gflops)]);
    }
    println!("{t}\n");
    let crit = critical::fit_opcount_critical(&sweep, 0.9);
    println!("OpCount_critical  paper: {}   derived: {}\n",
             fmt_gops(10f64.powf(1.25)), fmt_gops(crit));

    // ---- step 2: PCA feature ranking (Section II.B) ----
    let layers = microbench::conv_sweep();
    let ch = features::characterize(&sim, &layers, 1);
    let mut t = Table::new(&["feature", "|corr with perf|"]).label_first()
        .with_title("feature association with achieved performance");
    for (name, assoc) in features::FEATURE_NAMES.iter().zip(ch.perf_association) {
        t.row(vec![name.to_string(), format!("{assoc:.3}")]);
    }
    println!("{t}");
    let ratios = ch.pca.explained_ratio();
    println!("PCA explained variance: PC1 {:.1}%  PC2 {:.1}%\n",
             100.0 * ratios[0], 100.0 * ratios[1]);

    // ---- step 3: Eq. 5 weight fit ----
    let fitted = MpModel::fit(&sim, &layers);
    println!("Eq. 5 weights      paper: alpha=0.316 beta=0.659");
    println!("                 derived: alpha={:.3} beta={:.3} bias={:.3}",
             fitted.alpha, fitted.beta, fitted.bias);

    // Show the derived selector against the simulator optimum on a few
    // familiar layers.
    let mut t = Table::new(&["layer", "simulator best MP", "Eq.5 MP"]).label_first()
        .with_title("\nMP selection spot-check");
    for (name, layer) in [
        ("vgg conv1_2 {64,64,224^2}", microbench::channel_scaled_series(&[1])[0].clone()),
        ("resnet mid {128,128,28^2}",
         dlfusion::graph::Layer::conv("r", dlfusion::graph::ConvSpec::same(128, 128, 28, 3))),
        ("vgg late {512,512,28^2}",
         dlfusion::graph::Layer::conv("v", dlfusion::graph::ConvSpec::same(512, 512, 28, 3))),
    ] {
        t.row(vec![
            name.to_string(),
            sim.best_layer_mp(&layer).to_string(),
            fitted.select_layer(&sim.spec, &layer).to_string(),
        ]);
    }
    println!("{t}");
}
