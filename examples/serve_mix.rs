//! Serve a multi-model traffic mix on the simulated 32-core pool.
//!
//! Walks the whole serving path: allocate per-model MP under load, generate
//! a seeded Poisson trace, run the deterministic event-driven simulation,
//! and print the SLO report — the serving-level counterpart of the
//! per-inference `quickstart` example.
//!
//! ```bash
//! cargo run --release --example serve_mix
//! ```

use dlfusion::accel::{Simulator, Target};
use dlfusion::serving::{self, AllocationRequest, ArrivalProcess,
                        ClusterConfig, DispatchPolicy, ModelMix,
                        SimulationRun, SloReport};
use dlfusion::zoo;

fn main() {
    let sim = Simulator::new(Target::mlu100());
    // 3:1 ResNet-18 : VGG-19 traffic, a 40 ms end-to-end SLO.
    let mix = ModelMix::weighted(vec![zoo::resnet18(), zoo::vgg19()],
                                 vec![3.0, 1.0]);
    let slo_ms = Some(40.0);

    let plan = AllocationRequest::new(&sim, &mix)
        .slo_ms(slo_ms)
        .plan()
        .expect("allocation");
    print!("{}", plan.render());
    println!("predicted capacity on {} cores: {:.0} req/s load-aware vs \
              {:.0} req/s single-request",
             sim.spec.num_cores,
             plan.predicted_capacity_rps(sim.spec.num_cores, true),
             plan.predicted_capacity_rps(sim.spec.num_cores, false));

    // Offer 80% of the load-aware capacity as Poisson traffic.
    let rate = 0.8 * plan.predicted_capacity_rps(sim.spec.num_cores, true);
    let trace = serving::generate_trace(
        &mix, ArrivalProcess::OpenPoisson { rate_rps: rate }, 2000, 7);
    let cfg = ClusterConfig { num_cores: sim.spec.num_cores,
                              policy: DispatchPolicy::Fifo };

    for (label, load_aware) in [("single-request", false), ("load-aware", true)] {
        let result = SimulationRun::new(&cfg, &plan.services(load_aware))
            .trace(&trace)
            .run()
            .expect("simulate");
        println!("\n--- {label} allocation, {:.0} req/s offered ---", rate);
        print!("{}", SloReport::from_sim(&result, slo_ms).render());
    }

    // Third knob: dynamic batching. The (mp_cap, batch) sweep prices each
    // tuned schedule at every batch, and the `batch` dispatch policy forms
    // per-model batches whose invocations amortize the weight fetch.
    let max_batch = serving::DEFAULT_MAX_BATCH;
    let batched = AllocationRequest::new(&sim, &mix)
        .slo_ms(slo_ms)
        .max_batch(max_batch)
        .plan()
        .expect("allocation");
    println!("\npredicted batched capacity: {:.0} req/s at the load-aware \
              batches (vs {:.0} req/s one-at-a-time)",
             batched.predicted_batched_capacity_rps(sim.spec.num_cores),
             batched.predicted_capacity_rps(sim.spec.num_cores, true));
    let cfg = ClusterConfig {
        num_cores: sim.spec.num_cores,
        policy: DispatchPolicy::Batch {
            max_batch,
            max_wait_ms: serving::DEFAULT_BATCH_WAIT_MS,
        },
    };
    let result = SimulationRun::new(&cfg, &batched.services(true))
        .trace(&trace)
        .run()
        .expect("simulate");
    println!("\n--- load-aware allocation, batch dispatch ---");
    print!("{}", SloReport::from_sim(&result, slo_ms).render());
}
